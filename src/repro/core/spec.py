"""Declarative workflow specs: serialize a StageGraph to a versioned,
schema-validated document and back (paper §4.1 — workflows as shareable,
expert-crafted artifacts a non-expert can inspect and run).

A *spec* is a plain JSON-able dict (stored as ``.json`` or, when PyYAML
is available, ``.yaml``) describing a workflow completely: stages with
their declared input/output ports, dependency edges, per-stage resource
intents, retry policies, placement bindings and cache/resume knobs —
everything the static checker (:mod:`repro.core.check`) needs *before*
any cloud resource is provisioned, and everything ``from_spec`` needs to
rebuild an executable graph.

Three document kinds share the ``spec_version`` envelope:

  * ``kind: workflow`` — one stage graph (:func:`to_spec` /
    :func:`from_spec`);
  * ``kind: package`` — a workflow bundled with its template and run
    params into one shareable artifact (:func:`pack_template` /
    :func:`unpack_package`; the CLI's ``pack`` / ``unpack`` verbs);
  * nested ``graph`` blocks — subworkflow stages serialize their inner
    graph recursively.

Determinism: :func:`dumps_spec` renders with sorted keys and a fixed
indent, and :func:`to_spec` round-trips its result through JSON, so the
same graph always yields byte-identical text — specs diff cleanly and
golden files stay stable.

What does *not* survive serialization (each refused loudly rather than
dropped silently):

  * non-JSON-able constructor knobs (callables, live objects) become
    ``{"__opaque__": <type>}`` markers; ``from_spec(strict=True)``
    refuses to rebuild an executable stage from them and the checker
    flags them on cacheable stages (ADV008);
  * ``RestartPolicy.retry_on`` (a tuple of exception *classes*) —
    reconstructed policies use the default retryable set;
  * ``FnStage`` bodies — wrap real logic in a named Stage subclass and
    :func:`register_stage_type` it to make a workflow shareable.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, List, Optional, Sequence, Tuple, Type

from repro.core.graph import Stage, StageContext, StageGraph, _SubworkflowStage
from repro.core.intent import ResourceIntent
from repro.core.stages import (
    CalibrateStage,
    DataStage,
    EvalStage,
    ExploreStage,
    MoveStage,
    PlanStage,
    ServeStage,
    TrainStage,
    ValidateStage,
    VisualizeStage,
)
from repro.ft.failures import RestartPolicy

SPEC_VERSION = "1"

# entry fields every stage entry carries (validate_spec rejects others)
_ENTRY_KEYS = frozenset({
    "name", "type", "depends_on", "inputs", "outputs", "config",
    "intent", "retry", "placement_key", "checks", "cacheable",
    "cache_params", "cache_template_fields", "cache_version",
    "resume_payload", "unpicklable_outputs", "graph", "inner_retry",
    "meta",
})
_DOC_KEYS = frozenset({
    "spec_version", "kind", "name", "stages", "external_inputs",
    "results", "waivers", "budget_usd", "meta",
})
_PACKAGE_KEYS = frozenset({
    "spec_version", "kind", "name", "template", "workflow", "params",
    "meta",
})
_RETRY_FIELDS = ("max_restarts", "backoff_s", "max_backoff_s", "jitter",
                 "seed")


class SpecError(ValueError):
    """A spec document that can't be validated or reconstructed."""


# ===========================================================================
# Stage-type registry
# ===========================================================================
STAGE_TYPES: Dict[str, Type[Stage]] = {}
_TYPE_NAMES: Dict[Type[Stage], str] = {}


def register_stage_type(type_name: str, cls: Type[Stage]) -> None:
    """Make a Stage subclass reconstructable from specs under
    ``type_name`` (and serialized under it by :func:`to_spec`).  The
    class must honor the ``spec_config`` / ``from_spec_config``
    contract (see :class:`repro.core.graph.Stage`)."""
    STAGE_TYPES[type_name] = cls
    _TYPE_NAMES[cls] = type_name


for _tname, _tcls in (
    ("plan", PlanStage), ("data", DataStage), ("train", TrainStage),
    ("serve", ServeStage), ("explore", ExploreStage), ("eval", EvalStage),
    ("validate", ValidateStage), ("visualize", VisualizeStage),
    ("move", MoveStage), ("calibrate", CalibrateStage),
):
    register_stage_type(_tname, _tcls)


class DeclaredStage(Stage):
    """A stage known only by declaration — ports, deps and config from a
    spec, no executable body.

    ``from_spec(strict=False)`` falls back to this for unknown types and
    opaque configs so the *static checker* can analyze any well-formed
    spec; authors can also use ``type: declared`` directly to sketch a
    workflow's dataflow before the implementation exists.  Executing one
    raises :class:`SpecError`.
    """

    def __init__(self, name: str, inputs: Sequence[str] = (),
                 outputs: Sequence[str] = (),
                 declared_type: str = "declared",
                 config: Optional[Dict[str, Any]] = None):
        super().__init__(name)
        self.inputs = tuple(inputs)
        self.outputs = tuple(outputs)
        self.declared_type = declared_type
        self.declared_config = dict(config or {})

    def spec_config(self) -> Dict[str, Any]:
        return dict(self.declared_config)

    def run(self, ctx: StageContext) -> Dict[str, Any]:
        raise SpecError(
            f"stage {self.name!r} (type {self.declared_type!r}) is "
            f"declaration-only: its spec could not be bound to an "
            f"executable stage class (register one with "
            f"repro.core.spec.register_stage_type)"
        )


register_stage_type("declared", DeclaredStage)


def _type_name(stage: Stage) -> str:
    if isinstance(stage, _SubworkflowStage):
        return "subworkflow"
    if isinstance(stage, DeclaredStage):
        return stage.declared_type
    return _TYPE_NAMES.get(type(stage), type(stage).__name__)


def opaque_paths(config: Any, _prefix: str = "") -> List[str]:
    """Dotted paths of every ``{"__opaque__": ...}`` marker in a spec
    config block — non-empty means the config can't rebuild a stage."""
    out: List[str] = []
    if isinstance(config, dict):
        if set(config) == {"__opaque__"}:
            return [_prefix.rstrip(".") or "<config>"]
        for k, v in config.items():
            out.extend(opaque_paths(v, f"{_prefix}{k}."))
    elif isinstance(config, list):
        for i, v in enumerate(config):
            out.extend(opaque_paths(v, f"{_prefix}{i}."))
    return out


# ===========================================================================
# Graph -> spec
# ===========================================================================
def _intent_doc(intent: Optional[ResourceIntent]) -> Optional[Dict[str, Any]]:
    return dataclasses.asdict(intent) if intent is not None else None


def _retry_doc(retry: Optional[RestartPolicy]) -> Optional[Dict[str, Any]]:
    # retry_on holds exception *classes* — not serializable; reloaded
    # policies fall back to the default retryable set (module docstring)
    if retry is None:
        return None
    return {f: getattr(retry, f) for f in _RETRY_FIELDS}


def _stage_entry(name: str, stage: Stage,
                 depends_on: Tuple[str, ...]) -> Dict[str, Any]:
    entry: Dict[str, Any] = {
        "name": name,
        "type": _type_name(stage),
        "depends_on": list(depends_on),
        "inputs": list(stage.inputs),
        "outputs": list(stage.outputs),
        "config": stage.spec_config(),
        "intent": _intent_doc(stage.intent),
        "retry": _retry_doc(stage.retry),
        "placement_key": stage.placement_key,
        "checks": list(stage.checks) if stage.checks is not None else None,
        "cacheable": stage.cacheable,
        "cache_params": list(stage.cache_params),
        "cache_template_fields": (list(stage.cache_template_fields)
                                  if stage.cache_template_fields is not None
                                  else None),
        "cache_version": stage.cache_version,
        "resume_payload": stage.resume_payload,
        "unpicklable_outputs": list(stage.unpicklable_outputs),
    }
    if isinstance(stage, _SubworkflowStage):
        entry["graph"] = to_spec(stage.graph)
        entry["inner_retry"] = _retry_doc(stage.inner_retry)
    return entry


def default_results(graph: StageGraph) -> List[str]:
    """The keys a workflow is *for*: every produced-but-unconsumed
    output.  ``to_spec`` records them so the dead-output lint (ADV002)
    knows terminal artifacts from genuinely dropped values."""
    produced = [k for s in graph.stages.values() for k in s.outputs]
    consumed = {k for s in graph.stages.values() for k in s.inputs}
    return sorted(set(produced) - consumed)


def to_spec(graph: StageGraph, *, name: Optional[str] = None,
            results: Optional[Sequence[str]] = None,
            waivers: Sequence[Dict[str, Any]] = (),
            external_inputs: Sequence[str] = (),
            budget_usd: Optional[float] = None) -> Dict[str, Any]:
    """Serialize a graph into a workflow spec document (pure JSON types,
    byte-deterministic through :func:`dumps_spec`).

    ``results`` defaults to :func:`default_results`; ``external_inputs``
    names keys the runner seeds (params, pre-loaded context) so the
    checker doesn't flag them as unproduced; ``waivers`` are
    per-diagnostic suppressions (``{"code", "stage", "reason"}``, stage
    None = any); ``budget_usd`` attaches the envelope the over-budget
    check (ADV007) enforces.
    """
    graph.validate()
    doc = {
        "spec_version": SPEC_VERSION,
        "kind": "workflow",
        "name": name or graph.name,
        "external_inputs": sorted(set(external_inputs)),
        "results": (sorted(set(results)) if results is not None
                    else default_results(graph)),
        "waivers": [dict(w) for w in waivers],
        "budget_usd": budget_usd,
        "stages": [_stage_entry(n, graph.stages[n], graph.deps(n))
                   for n in graph.stages],  # insertion order
    }
    # normalize tuples/np scalars through the JSON renderer so the
    # returned dict contains exactly what a reloaded file would
    return json.loads(dumps_spec(doc))


# ===========================================================================
# Spec -> graph
# ===========================================================================
def _apply(stage: Stage, attr: str, value: Any) -> None:
    """Set an entry-level attribute only when it differs from what the
    constructor produced — keeps ``vars(stage)`` (and therefore cache
    signatures) identical for faithful round-trips."""
    if getattr(stage, attr) != value:
        setattr(stage, attr, value)


def _build_stage(entry: Dict[str, Any], strict: bool) -> Stage:
    name = entry["name"]
    tname = entry["type"]
    config = entry.get("config") or {}
    if tname == "subworkflow":
        inner = from_spec(entry["graph"], strict=strict)
        inner_retry = _retry_from(entry.get("inner_retry"))
        return inner.as_stage(name,
                              max_workers=int(config.get("max_workers", 4)),
                              retry=inner_retry)
    cls = STAGE_TYPES.get(tname)
    opaque = opaque_paths(config)
    if cls is None or (opaque and cls is not DeclaredStage):
        why = (f"unknown stage type {tname!r}" if cls is None else
               f"opaque config value(s) at {', '.join(opaque)}")
        if strict:
            raise SpecError(
                f"stage {name!r}: {why} — cannot rebuild an executable "
                f"stage (load with strict=False for analysis-only, or "
                f"register the type via register_stage_type)")
        return DeclaredStage(name, inputs=entry.get("inputs", ()),
                             outputs=entry.get("outputs", ()),
                             declared_type=tname, config=config)
    if cls is DeclaredStage:
        # declaration-only stages take their ports from the entry, not
        # from config (which is free-form author metadata)
        return DeclaredStage(name, inputs=entry.get("inputs", ()),
                             outputs=entry.get("outputs", ()),
                             declared_type=tname, config=config)
    try:
        stage = cls.from_spec_config(name, config)
    except TypeError as e:
        raise SpecError(
            f"stage {name!r}: config does not match {cls.__name__} "
            f"constructor ({e})") from e
    return stage


def _retry_from(doc: Optional[Dict[str, Any]]) -> Optional[RestartPolicy]:
    if doc is None:
        return None
    return RestartPolicy(**{f: doc[f] for f in _RETRY_FIELDS if f in doc})


def _intent_from(doc: Optional[Dict[str, Any]]) -> Optional[ResourceIntent]:
    if doc is None:
        return None
    kw = dict(doc)
    if kw.get("mesh_shape") is not None:
        kw["mesh_shape"] = tuple(kw["mesh_shape"])
    try:
        return ResourceIntent(**kw)
    except TypeError as e:
        raise SpecError(f"bad intent block {sorted(doc)}: {e}") from e


def from_spec(doc: Dict[str, Any], *, strict: bool = True) -> StageGraph:
    """Rebuild a StageGraph from a workflow spec document.

    ``strict=True`` (the default, what ``run`` uses) requires every
    stage to bind to a registered executable class with a fully
    concrete config; ``strict=False`` (what ``check`` uses) degrades
    unknown types and opaque configs to :class:`DeclaredStage` so
    static analysis works on any well-formed spec.  Either way the
    declared ports must match what the stage class derives from its
    config — a drifted spec fails here, not mid-run.
    """
    errors = validate_spec(doc)
    if errors:
        raise SpecError("invalid spec: " + "; ".join(errors))
    g = StageGraph(doc["name"])
    for entry in doc["stages"]:
        stage = _build_stage(entry, strict)
        declared_in = tuple(entry.get("inputs", ()))
        declared_out = tuple(entry.get("outputs", ()))
        if not isinstance(stage, DeclaredStage):
            if (tuple(stage.inputs) != declared_in
                    or tuple(stage.outputs) != declared_out):
                raise SpecError(
                    f"stage {entry['name']!r}: declared ports "
                    f"(in={list(declared_in)}, out={list(declared_out)}) "
                    f"do not match what {type(stage).__name__} derives "
                    f"from its config (in={list(stage.inputs)}, "
                    f"out={list(stage.outputs)}) — the spec has drifted "
                    f"from the stage implementation")
        _apply(stage, "intent", _intent_from(entry.get("intent")))
        _apply(stage, "retry", _retry_from(entry.get("retry")))
        _apply(stage, "placement_key", entry.get("placement_key"))
        checks = entry.get("checks")
        _apply(stage, "checks",
               tuple(checks) if checks is not None else None)
        _apply(stage, "cacheable", bool(entry.get("cacheable", False)))
        _apply(stage, "cache_params", tuple(entry.get("cache_params", ())))
        ctf = entry.get("cache_template_fields")
        _apply(stage, "cache_template_fields",
               tuple(ctf) if ctf is not None else None)
        _apply(stage, "cache_version", entry.get("cache_version", "1"))
        _apply(stage, "resume_payload",
               bool(entry.get("resume_payload", True)))
        _apply(stage, "unpicklable_outputs",
               tuple(entry.get("unpicklable_outputs", ())))
        g.add(stage, depends_on=tuple(entry.get("depends_on", ())))
    return g


# ===========================================================================
# Schema validation (hand-rolled: no jsonschema dependency)
# ===========================================================================
def _type_err(where: str, what: str, value: Any) -> str:
    return f"{where}: expected {what}, got {type(value).__name__}"


def _check_str_list(errors: List[str], where: str, value: Any) -> None:
    if not isinstance(value, list) or not all(
            isinstance(x, str) for x in value):
        errors.append(_type_err(where, "a list of strings", value))


def validate_spec(doc: Any) -> List[str]:
    """Schema errors for a spec document (empty list = valid).  Checks
    the envelope, required fields, field types, stage-name uniqueness
    and unknown keys — the ADV010 layer; graph-structure problems
    (cycles, unknown deps) surface when the graph is built (ADV011)."""
    errors: List[str] = []
    if not isinstance(doc, dict):
        return [_type_err("document", "a mapping", doc)]
    version = doc.get("spec_version")
    if version is None:
        errors.append("missing required field 'spec_version'")
    elif str(version) != SPEC_VERSION:
        errors.append(f"unsupported spec_version {version!r} "
                      f"(this build reads {SPEC_VERSION!r})")
    kind = doc.get("kind", "workflow")
    if kind == "package":
        for unknown in sorted(set(doc) - _PACKAGE_KEYS):
            errors.append(f"unknown package field {unknown!r}")
        wf = doc.get("workflow")
        if not isinstance(wf, dict):
            errors.append(_type_err("package 'workflow'", "a mapping", wf))
        else:
            errors.extend(validate_spec(wf))
        if "params" in doc and not isinstance(doc["params"], dict):
            errors.append(_type_err("package 'params'", "a mapping",
                                    doc["params"]))
        return errors
    if kind != "workflow":
        errors.append(f"unknown kind {kind!r} (expected 'workflow' or "
                      f"'package')")
        return errors
    for unknown in sorted(set(doc) - _DOC_KEYS):
        errors.append(f"unknown workflow field {unknown!r}")
    if not isinstance(doc.get("name"), str) or not doc.get("name"):
        errors.append("workflow 'name' must be a non-empty string")
    for key in ("external_inputs", "results"):
        if key in doc:
            _check_str_list(errors, f"workflow {key!r}", doc[key])
    if "waivers" in doc:
        if not isinstance(doc["waivers"], list):
            errors.append(_type_err("workflow 'waivers'", "a list",
                                    doc["waivers"]))
        else:
            for i, w in enumerate(doc["waivers"]):
                if not isinstance(w, dict) or not isinstance(
                        w.get("code"), str):
                    errors.append(f"waivers[{i}]: must be a mapping with "
                                  f"a string 'code'")
    if "budget_usd" in doc and doc["budget_usd"] is not None \
            and not isinstance(doc["budget_usd"], (int, float)):
        errors.append(_type_err("workflow 'budget_usd'", "a number",
                                doc["budget_usd"]))
    stages = doc.get("stages")
    if not isinstance(stages, list):
        errors.append(_type_err("workflow 'stages'", "a list", stages))
        return errors
    seen: Dict[str, int] = {}
    for i, entry in enumerate(stages):
        where = f"stages[{i}]"
        if not isinstance(entry, dict):
            errors.append(_type_err(where, "a mapping", entry))
            continue
        name = entry.get("name")
        if not isinstance(name, str) or not name:
            errors.append(f"{where}: 'name' must be a non-empty string")
        elif name in seen:
            errors.append(f"{where}: duplicate stage name {name!r} "
                          f"(first at stages[{seen[name]}])")
        else:
            seen[name] = i
            where = f"stages[{i}] ({name!r})"
        if not isinstance(entry.get("type"), str):
            errors.append(f"{where}: 'type' must be a string")
        for unknown in sorted(set(entry) - _ENTRY_KEYS):
            errors.append(f"{where}: unknown field {unknown!r}")
        for key in ("depends_on", "inputs", "outputs", "cache_params",
                    "unpicklable_outputs"):
            if key in entry:
                _check_str_list(errors, f"{where} {key!r}", entry[key])
        for key in ("checks", "cache_template_fields"):
            if entry.get(key) is not None and key in entry:
                _check_str_list(errors, f"{where} {key!r}", entry[key])
        if "config" in entry and not isinstance(entry["config"], dict):
            errors.append(_type_err(f"{where} 'config'", "a mapping",
                                    entry["config"]))
        for key in ("intent", "retry"):
            if entry.get(key) is not None and not isinstance(
                    entry[key], dict):
                errors.append(_type_err(f"{where} {key!r}", "a mapping",
                                        entry[key]))
        if entry.get("type") == "subworkflow":
            if not isinstance(entry.get("graph"), dict):
                errors.append(f"{where}: subworkflow entries need a "
                              f"'graph' block")
            else:
                errors.extend(f"{where}.graph: {e}"
                              for e in validate_spec(entry["graph"]))
    return errors


# ===========================================================================
# Rendering & files
# ===========================================================================
def dumps_spec(doc: Dict[str, Any]) -> str:
    """The canonical text rendering: sorted keys, fixed indent, trailing
    newline — byte-identical for equal documents."""
    return json.dumps(doc, indent=1, sort_keys=True, default=_json_default) \
        + "\n"


def _json_default(v: Any) -> Any:
    if isinstance(v, tuple):
        return list(v)
    if hasattr(v, "item"):  # numpy scalar
        return v.item()
    raise TypeError(f"not spec-serializable: {type(v).__name__}")


def dump_spec(doc: Dict[str, Any], path: str) -> None:
    """Write a spec to ``path``; format chosen by extension (``.json``
    canonical; ``.yaml``/``.yml`` when PyYAML is installed)."""
    if path.endswith((".yaml", ".yml")):
        yaml = _yaml()
        text = yaml.safe_dump(doc, sort_keys=True,
                              default_flow_style=False)
    else:
        text = dumps_spec(doc)
    with open(path, "w", encoding="utf-8") as f:
        f.write(text)


def load_spec(path: str) -> Dict[str, Any]:
    """Read a spec document from a ``.json`` / ``.yaml`` file (no
    validation — pair with :func:`validate_spec` / :func:`from_spec`)."""
    with open(path, encoding="utf-8") as f:
        text = f.read()
    if path.endswith((".yaml", ".yml")):
        doc = _yaml().safe_load(text)
    else:
        try:
            doc = json.loads(text)
        except json.JSONDecodeError as e:
            raise SpecError(f"{path}: not valid JSON ({e})") from e
    if not isinstance(doc, dict):
        raise SpecError(f"{path}: expected a mapping at top level")
    return doc


def _yaml():
    try:
        import yaml
    except ImportError as e:  # pragma: no cover - env-dependent
        raise SpecError(
            "YAML specs need PyYAML, which is not installed — use the "
            ".json form (canonical) instead") from e
    return yaml


# ===========================================================================
# Templates: serialize, package, register
# ===========================================================================
def template_to_spec(t: Any) -> Dict[str, Any]:
    """A WorkflowTemplate as pure JSON types (nested data/optimizer
    configs by field)."""
    return json.loads(json.dumps(dataclasses.asdict(t),
                                 default=_json_default))


def template_from_spec(doc: Dict[str, Any]) -> Any:
    from repro.core.workflow import WorkflowTemplate
    from repro.data import DataConfig
    from repro.train import OptimizerConfig

    kw = dict(doc)
    unknown = sorted(set(kw) - {f.name for f in
                                dataclasses.fields(WorkflowTemplate)})
    if unknown:
        raise SpecError(f"unknown template field(s) {unknown}")
    try:
        if isinstance(kw.get("data"), dict):
            kw["data"] = DataConfig(**kw["data"])
        if isinstance(kw.get("optimizer"), dict):
            opt = dict(kw["optimizer"])
            if isinstance(opt.get("betas"), list):
                opt["betas"] = tuple(opt["betas"])
            kw["optimizer"] = OptimizerConfig(**opt)
        if isinstance(kw.get("checks"), list):
            kw["checks"] = tuple(kw["checks"])
        return WorkflowTemplate(**kw)
    except TypeError as e:
        raise SpecError(f"bad template block: {e}") from e


def default_waivers(t: Any) -> List[Dict[str, Any]]:
    """The waivers canonical templates ship with.  ADV005 (cross-slice
    handoff without a movement stage) is waived because the bundled
    executor is single-process: every stage shares one in-memory
    blackboard, so the handoff is logical until a movement lowering
    (:func:`repro.core.check.insert_movement_stages`) is applied."""
    return [{
        "code": "ADV005",
        "stage": None,
        "reason": "single-process executor shares one in-memory "
                  "blackboard; apply insert_movement_stages to make "
                  "cross-slice handoffs explicit",
    }]


def spec_for_template(t: Any, *, with_eval: bool = False) -> Dict[str, Any]:
    """The canonical workflow spec of a registry template: its compiled
    graph serialized with the template's default waivers."""
    from repro.core.workflow import compile_template

    g = compile_template(t, with_eval=with_eval)
    return to_spec(g, name=t.name, waivers=default_waivers(t))


def pack_template(t: Any, *, with_eval: bool = False,
                  params: Optional[Dict[str, Any]] = None,
                  ) -> Dict[str, Any]:
    """Bundle template + compiled workflow + run params into one
    shareable package document (the CLI's ``pack``)."""
    doc = {
        "spec_version": SPEC_VERSION,
        "kind": "package",
        "name": t.name,
        "template": template_to_spec(t),
        "workflow": spec_for_template(t, with_eval=with_eval),
        "params": dict(params or {}),
    }
    return json.loads(dumps_spec(doc))


def unpack_package(doc: Dict[str, Any]) -> Tuple[Any, Dict[str, Any],
                                                 Dict[str, Any]]:
    """(template, workflow_doc, params) from a package document.  The
    workflow doc is returned unparsed so the caller picks strictness."""
    errors = validate_spec(doc)
    if errors:
        raise SpecError("invalid package: " + "; ".join(errors))
    if doc.get("kind") != "package":
        raise SpecError(f"expected kind 'package', got {doc.get('kind')!r}")
    template = None
    if doc.get("template") is not None:
        template = template_from_spec(doc["template"])
    return template, doc["workflow"], dict(doc.get("params") or {})


def load_workflow(path: str, *, strict: bool = True,
                  ) -> Tuple[Optional[Any], StageGraph, Dict[str, Any],
                             Dict[str, Any]]:
    """One-call loader for either document kind on disk:
    ``(template, graph, params, workflow_doc)``.  Workflow-kind files
    yield ``template=None`` and empty params."""
    doc = load_spec(path)
    if doc.get("kind") == "package":
        template, wf_doc, params = unpack_package(doc)
    else:
        template, wf_doc, params = None, doc, {}
    return template, from_spec(wf_doc, strict=strict), params, wf_doc


__all__ = [
    "SPEC_VERSION", "SpecError", "STAGE_TYPES", "DeclaredStage",
    "register_stage_type", "opaque_paths", "to_spec", "from_spec",
    "default_results", "validate_spec", "dumps_spec", "dump_spec",
    "load_spec", "template_to_spec", "template_from_spec",
    "default_waivers", "spec_for_template", "pack_template",
    "unpack_package", "load_workflow",
]
