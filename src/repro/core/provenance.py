"""Job Results & Provenance (paper §4.4): the persistent record of
computation.

Every run is linked to {template name+version, config hash, plan, mesh,
environment} so teams can reproduce baselines, compare runs across
backends, and diff parameter injections (the paper's q=0.25 → 0.5 PISM
example).  Storage is a plain directory tree — no services required:

    runs/<run_id>/manifest.json     # identity + environment + plan
    runs/<run_id>/metrics.jsonl     # one json per step
    runs/<run_id>/artifacts/...     # checkpoints, figures, reports
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import platform
import time
from typing import Any, Dict, Iterator, List, Optional

import jax


def stable_hash(obj: Any) -> str:
    def default(o):
        if dataclasses.is_dataclass(o):
            return dataclasses.asdict(o)
        if isinstance(o, tuple):
            return list(o)
        return str(o)

    payload = json.dumps(obj, sort_keys=True, default=default)
    return hashlib.sha256(payload.encode()).hexdigest()[:16]


def capture_environment() -> Dict[str, Any]:
    return {
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
        "device_kind": jax.devices()[0].device_kind,
        "device_count": jax.device_count(),
        "python": platform.python_version(),
        "platform": platform.platform(),
        "xla_flags": os.environ.get("XLA_FLAGS", ""),
        "kernel_backend": os.environ.get("REPRO_KERNEL_BACKEND", "ref"),
    }


class RunRecord:
    def __init__(self, root: str, run_id: str, manifest: Dict[str, Any]):
        self.run_id = run_id
        self.dir = os.path.join(root, run_id)
        os.makedirs(os.path.join(self.dir, "artifacts"), exist_ok=True)
        self.manifest = manifest
        with open(os.path.join(self.dir, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1, default=str)
        self._metrics_path = os.path.join(self.dir, "metrics.jsonl")

    def log(self, step: int, metrics: Dict[str, Any]) -> None:
        row = {"step": int(step), "t": time.time()}
        for k, v in metrics.items():
            try:
                row[k] = float(v)
            except (TypeError, ValueError):
                row[k] = str(v)
        with open(self._metrics_path, "a") as f:
            f.write(json.dumps(row) + "\n")

    def log_event(self, kind: str, payload: Dict[str, Any]) -> None:
        with open(os.path.join(self.dir, "events.jsonl"), "a") as f:
            f.write(json.dumps({"kind": kind, "t": time.time(), **payload},
                               default=str) + "\n")

    def update_manifest(self, **patch: Any) -> None:
        """Merge keys into the manifest and rewrite manifest.json — used by
        stages that learn facts after run creation (e.g. the resolved plan)."""
        self.manifest.update(patch)
        with open(os.path.join(self.dir, "manifest.json"), "w") as f:
            json.dump(self.manifest, f, indent=1, default=str)

    @property
    def artifacts_dir(self) -> str:
        return os.path.join(self.dir, "artifacts")

    def metrics(self) -> List[Dict[str, Any]]:
        if not os.path.exists(self._metrics_path):
            return []
        with open(self._metrics_path) as f:
            return [json.loads(line) for line in f if line.strip()]

    def events(self) -> List[Dict[str, Any]]:
        path = os.path.join(self.dir, "events.jsonl")
        if not os.path.exists(path):
            return []
        with open(path) as f:
            return [json.loads(line) for line in f if line.strip()]

    def stage_events(self) -> List[Dict[str, Any]]:
        """The per-stage provenance trail emitted by StageGraph.execute
        and the executor backends: placement (resolved backend binding),
        stage_start, stage_cached (cache or resume skip), stage_failed /
        stage_retry (fault tolerance), stage_lease / stage_worker /
        worker_recruited / worker_lost (executor worker attribution —
        see docs/executors.md), and stage_end rows with timing and
        outputs hash."""
        return [e for e in self.events()
                if e.get("kind") in ("placement", "stage_start",
                                     "stage_cached", "stage_failed",
                                     "stage_retry", "stage_end",
                                     "stage_lease", "stage_worker",
                                     "worker_recruited", "worker_lost")]

    def stage_view(self, stage: str) -> "StageRecordView":
        return StageRecordView(self, stage)


class StageRecordView:
    """A RunRecord facade scoped to one stage: metric rows gain a
    ``stage`` column and events a ``stage`` field, so concurrent stages
    (e.g. a fan-out sweep's train stages) can share one run record while
    staying separable; ``metrics()`` reads back only this stage's rows."""

    def __init__(self, record: RunRecord, stage: str):
        self._record = record
        self.stage = stage
        self.run_id = record.run_id
        self.dir = record.dir

    @property
    def artifacts_dir(self) -> str:
        return self._record.artifacts_dir

    @property
    def manifest(self) -> Dict[str, Any]:
        return self._record.manifest

    def log(self, step: int, metrics: Dict[str, Any]) -> None:
        self._record.log(step, {**metrics, "stage": self.stage})

    def log_event(self, kind: str, payload: Dict[str, Any]) -> None:
        self._record.log_event(kind, {"stage": self.stage, **payload})

    def metrics(self) -> List[Dict[str, Any]]:
        return [r for r in self._record.metrics()
                if r.get("stage") == self.stage]


class ProvenanceStore:
    def __init__(self, root: str):
        self.root = root
        os.makedirs(root, exist_ok=True)

    def create_run(self, *, template: str, template_version: str,
                   config: Dict[str, Any], plan: Dict[str, Any],
                   workspace: str = "default",
                   parent_run: Optional[str] = None) -> RunRecord:
        config_hash = stable_hash(config)
        run_id = f"{template}-{config_hash}-{int(time.time()*1000) % 10**8:08d}"
        manifest = {
            "run_id": run_id,
            "template": template,
            "template_version": template_version,
            "config": config,
            "config_hash": config_hash,
            "plan": plan,
            "workspace": workspace,
            "parent_run": parent_run,
            "environment": capture_environment(),
            "created": time.time(),
        }
        return RunRecord(self.root, run_id, manifest)

    def list_runs(self) -> List[str]:
        return sorted(
            d for d in os.listdir(self.root)
            if os.path.isdir(os.path.join(self.root, d))
        )

    def load(self, run_id: str) -> RunRecord:
        path = os.path.join(self.root, run_id, "manifest.json")
        with open(path) as f:
            manifest = json.load(f)
        rec = RunRecord.__new__(RunRecord)
        rec.run_id = run_id
        rec.dir = os.path.join(self.root, run_id)
        rec.manifest = manifest
        rec._metrics_path = os.path.join(rec.dir, "metrics.jsonl")
        return rec

    # ------------------------------------------------------------------
    def compare(self, run_a: str, run_b: str) -> Dict[str, Any]:
        """Config diff + final-metric deltas (the paper's 'systematic
        comparison across runs and backends')."""
        a, b = self.load(run_a), self.load(run_b)

        def flat(d, prefix=""):
            out = {}
            for k, v in d.items():
                key = f"{prefix}{k}"
                if isinstance(v, dict):
                    out.update(flat(v, key + "."))
                else:
                    out[key] = v
            return out

        ca, cb = flat(a.manifest.get("config", {})), flat(b.manifest.get("config", {}))
        config_diff = {
            k: {"a": ca.get(k), "b": cb.get(k)}
            for k in sorted(set(ca) | set(cb))
            if ca.get(k) != cb.get(k)
        }
        ma = a.metrics()
        mb = b.metrics()
        metric_delta = {}
        if ma and mb:
            last_a, last_b = ma[-1], mb[-1]
            for k in set(last_a) & set(last_b) - {"step", "t"}:
                if isinstance(last_a[k], float) and isinstance(last_b[k], float):
                    metric_delta[k] = {"a": last_a[k], "b": last_b[k],
                                       "delta": last_b[k] - last_a[k]}
        return {"config_diff": config_diff, "metric_delta": metric_delta}
