"""Built-in stage library: the monolithic run_workflow decomposed.

Each stage is one phase of the paper's workflow lifecycle (environment
setup, data processing, simulation/training, result capture,
visualization), reusable in any :class:`~repro.core.graph.StageGraph`:

  * :class:`PlanStage`      — resolve per-stage ResourceIntents into
                              PlanChoices, authorize budget, record plan
  * :class:`DataStage`      — model config + shape + synthetic stream
  * :class:`TrainStage`     — envelope-run training (per-stage overrides
                              enable fan-out sweeps over one shared record)
  * :class:`ServeStage`     — batched serving smoke via ServeEngine
  * :class:`EvalStage`      — held-out loss of a trained state
  * :class:`ValidateStage`  — template checks over the metric history
  * :class:`VisualizeStage` — loss-curve artifact
  * :class:`ExploreStage`   — cost-performance sweep
                              (:mod:`repro.core.explore`) with per-cell
                              stage-cache reuse and a Markdown artifact

The check functions themselves live here too (re-exported by
``repro.core.workflow`` for compatibility).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.graph import Stage, StageContext
from repro.core.intent import ResourceIntent
from repro.core.planner import plan_stages, to_runtime_plan


# ===========================================================================
# Validation checks — the early-failure nets templates carry
# ===========================================================================
def _check_loss_finite(history: List[Dict]) -> Tuple[bool, str]:
    bad = [h["step"] for h in history if not np.isfinite(h.get("loss", np.nan))]
    return (not bad, f"non-finite loss at steps {bad[:5]}" if bad else "all losses finite")


def _check_loss_decreased(history: List[Dict]) -> Tuple[bool, str]:
    losses = [h["loss"] for h in history if "loss" in h]
    if len(losses) < 4:
        return False, "too few steps to judge"
    k = max(2, len(losses) // 4)
    first, last = float(np.mean(losses[:k])), float(np.mean(losses[-k:]))
    return (last < first, f"loss {first:.4f} -> {last:.4f}")


def _check_grad_norm(history: List[Dict]) -> Tuple[bool, str]:
    gs = [h.get("grad_norm") for h in history if h.get("grad_norm") is not None]
    if not gs:
        return True, "no grad norms recorded"
    mx = max(gs)
    return (np.isfinite(mx) and mx < 1e4, f"max grad norm {mx:.2f}")


def _check_throughput(history: List[Dict]) -> Tuple[bool, str]:
    ts = [h.get("step_time_s", 0) for h in (history[1:] if len(history) > 1 else history)]
    return (bool(ts) and all(t > 0 for t in ts), f"median step {np.median(ts):.4f}s" if ts else "no steps")


CHECKS: Dict[str, Callable[[List[Dict]], Tuple[bool, str]]] = {
    "loss_finite": _check_loss_finite,
    "loss_decreased": _check_loss_decreased,
    "grad_norm_bounded": _check_grad_norm,
    "throughput_positive": _check_throughput,
}


def _reduced_workload(t, smoke_batch: int = 4,
                      smoke_seq: int = 32) -> Tuple[Any, Any, Any]:
    """(full_cfg, cfg, shape) for a template, honoring its scale."""
    from repro.configs import get_config, get_shape, reduced
    from repro.configs.base import ShapeConfig

    full_cfg = get_config(t.arch)
    cfg = reduced(full_cfg) if t.scale == "reduced" else full_cfg
    shape_full = get_shape(t.shape)
    if t.scale == "reduced":
        shape = ShapeConfig(shape_full.name, smoke_seq, smoke_batch,
                            shape_full.kind)
    else:
        shape = shape_full
    return full_cfg, cfg, shape


def _require_record(ctx: StageContext, stage: Stage, why: str) -> None:
    if ctx.record is None:
        raise ValueError(
            f"{type(stage).__name__} {stage.name!r} needs a StageContext "
            f"with a record ({why})"
        )


def _device_batch(raw: Dict[str, Any]) -> Dict[str, Any]:
    """Host batch -> device arrays, with the modality-specific bf16 casts
    shared by the train and eval stages."""
    import jax.numpy as jnp

    batch = {k: jnp.asarray(v) for k, v in raw.items()}
    if "frames" in batch:
        batch["frames"] = batch["frames"].astype(jnp.bfloat16)
    if "image_embeds" in batch:
        batch["image_embeds"] = batch["image_embeds"].astype(jnp.bfloat16)
    return batch


# ===========================================================================
# Plan
# ===========================================================================
class PlanStage(Stage):
    """Resolve one PlanChoice per stage and authorize the budget.

    ``stage_goals`` maps stage names to intent goals; each listed stage
    gets the main intent re-aimed at that goal and its own planner pass,
    so e.g. a data stage plans ``quick_test`` (smallest feasible slice)
    while train plans ``production``.  Outputs:

      * ``plan_choice``    — the main (train/serve) stage's winner
      * ``stage_plans``    — {stage_name: PlanChoice | None}; the
                             scheduler binds each listed stage to its
                             choice (``placement`` provenance events)
      * ``rt_plan``        — runtime sharding Plan for the main workload
      * ``projected_cost`` — $ projection used for the budget gate

    Budget protocol: this stage *authorizes* the projected spend (raising
    BudgetExceeded/PermissionDenied before any workload runs) but does
    not charge it — the runner charges ``projected_cost`` after the
    workload completes, as ``run_workflow`` does.  Custom runners that
    pass a ledger in the context must do the same.
    """

    outputs = ("plan_choice", "stage_plans", "rt_plan", "projected_cost")
    cache_params = ("intent", "steps_override")

    def __init__(self, name: str = "plan",
                 stage_goals: Optional[Dict[str, str]] = None):
        super().__init__(name)
        self.stage_goals = dict(stage_goals or {})

    def resume_safe(self, ctx: StageContext) -> bool:
        """Never skip on resume while a budget ledger is attached: the
        skip would restore the plan without re-running the
        ``ledger.authorize`` gate, letting a resumed run spend budget it
        was never granted."""
        return ctx.ledger is None

    def _main_intent(self, ctx: StageContext) -> ResourceIntent:
        intent = ctx.params.get("intent")
        if intent is None:
            intent = ctx.template.default_intent()
        return intent

    def run(self, ctx: StageContext) -> Dict[str, Any]:
        t = ctx.template
        intent = self._main_intent(ctx)
        intents = {"__main__": intent}
        for stage_name, goal in self.stage_goals.items():
            intents[stage_name] = intent.with_goal(goal)
        stage_plans = plan_stages(intents)
        choice = stage_plans.pop("__main__")

        projected = 0.0
        if choice is not None:
            steps = ctx.params.get("steps_override") or t.num_steps
            projected = choice.est.cost_per_step * steps
        if ctx.ledger is not None:
            ctx.ledger.authorize(ctx.workspace, ctx.user, t.name, projected)

        plan_doc = {
            "slice": choice.slice.name if choice else "local",
            "mesh_shape": choice.mesh_shape if choice else (1,),
            "est_step_s": choice.est.step_s if choice else None,
            "est_cost_per_step": choice.est.cost_per_step if choice else None,
            "bottleneck": choice.est.bottleneck if choice else None,
        }
        if choice is not None:
            # roofline terms + identity keys the calibration harvester
            # (repro.core.calibrate.harvest_run) pairs with measured step
            # times — without these a finished run contributes no telemetry
            from repro.configs import get_shape
            plan_doc.update(
                chip=choice.slice.chip.name,
                kind=get_shape(intent.shape).kind,
                compute_s=choice.est.compute_s,
                memory_s=choice.est.memory_s,
                collective_s=choice.est.collective_s,
                remat=choice.geometry.remat,
                microbatch=choice.geometry.microbatch,
            )
        if ctx.record is not None:
            placements_doc = {
                name: ({"slice": c.slice.name,
                        "mesh_shape": list(c.mesh_shape)}
                       if c is not None else None)
                for name, c in sorted(stage_plans.items())
            }
            ctx.record.update_manifest(plan=plan_doc,
                                       stage_placements=placements_doc)
            if choice is not None:
                ctx.record.log_event("plan", {"summary": choice.summary})
            for stage_name, c in sorted(stage_plans.items()):
                if c is not None:
                    ctx.record.log_event("plan", {"stage": stage_name,
                                                  "summary": c.summary})

        from repro.configs import get_config
        from repro.parallel.sharding import Plan as RuntimePlan

        rt_plan = (to_runtime_plan(choice, cfg=get_config(t.arch))
                   if choice else RuntimePlan())
        if t.scale == "reduced":
            rt_plan = rt_plan.with_(microbatch=1)
        return {"plan_choice": choice, "stage_plans": stage_plans,
                "rt_plan": rt_plan, "projected_cost": projected}


# ===========================================================================
# Data
# ===========================================================================
class DataStage(Stage):
    """Build the (possibly reduced) model config, shape and data stream.

    Cacheable across runs: the outputs are a pure function of the
    template's (arch, shape, scale, data) fields and the smoke knobs,
    so a sweep's fan-out or a re-run skips this stage on a cache hit.
    """

    outputs = ("full_cfg", "cfg", "shape", "stream")
    # pure python (configs + a seeded stream object, no jax, no record
    # writes) — safe to marshal into a process-pool child
    process_safe = True
    cacheable = True
    cache_params = ("smoke_batch", "smoke_seq")
    cache_template_fields = ("arch", "shape", "scale", "data")

    def __init__(self, name: str = "data", build_stream: bool = True):
        super().__init__(name)
        self.build_stream = build_stream

    def run(self, ctx: StageContext) -> Dict[str, Any]:
        from repro.data import make_stream

        t = ctx.template
        full_cfg, cfg, shape = _reduced_workload(
            t, smoke_batch=ctx.params.get("smoke_batch", 4),
            smoke_seq=ctx.params.get("smoke_seq", 32))
        stream = make_stream(cfg, shape, t.data) if self.build_stream else None
        return {"full_cfg": full_cfg, "cfg": cfg, "shape": shape,
                "stream": stream}


# ===========================================================================
# Train
# ===========================================================================
class TrainStage(Stage):
    """Envelope-run training.

    ``overrides`` applies template parameter injection for this stage
    only (a sweep's fan-out knob); ``state_key`` renames the produced
    state so several TrainStages can coexist in one graph.  Metrics and
    checkpoints are scoped per stage (stage column in metrics.jsonl,
    ``ckpt-<name>`` artifact dir), so concurrent trains stay separable.

    The train step is jitted with the state buffers donated
    (``donate=False`` or ctx param ``donate=False`` opts out): the state
    is updated in place instead of copied every step, which matters once
    the optimizer state stops fitting twice in HBM.

    Resilience: the stage checkpoints through the run's artifacts dir,
    so a retried or resumed attempt restores from the newest committed
    step automatically.  When the scheduler bound the stage to a
    placement (its resolved backend), the restore is placed directly
    onto that placement's mesh via
    :func:`repro.ft.elastic.state_shardings` — the elastic-restart path
    for a re-plan that landed on a different slice.
    """

    inputs = ("cfg", "shape", "stream", "rt_plan")
    placement_key = "__main__"
    cache_params = ("steps_override", "donate")
    # the checkpointer already persists the state in this run dir; a
    # resume re-enters run() and restores the newest committed step, so
    # pickling the full {params, opt} pytree into the run manifest would
    # only duplicate it
    resume_payload = False

    def __init__(self, name: str = "train",
                 overrides: Optional[Dict[str, Any]] = None,
                 state_key: str = "final_state",
                 donate: bool = True):
        super().__init__(name)
        self.overrides = dict(overrides or {})
        self.state_key = state_key
        self.donate = donate
        self.outputs = (state_key,)

    def run(self, ctx: StageContext) -> Dict[str, Any]:
        import jax

        from repro.checkpoint import Checkpointer
        from repro.core.envelope import ExecutionEnvelope
        from repro.models import build_model
        from repro.train import init_train_state, jit_train_step, make_train_step

        _require_record(ctx, self,
                        "the envelope logs metrics/checkpoints through it")
        t = ctx.template
        if self.overrides:
            t = t.with_overrides(**self.overrides)
        cfg = ctx.get("cfg")
        shape = ctx.get("shape")
        stream = ctx.get("stream")
        rt_plan = ctx.get("rt_plan")
        model = build_model(cfg)
        num_steps = ctx.params.get("steps_override") or t.num_steps

        donate = self.donate and ctx.params.get("donate", True)
        step_raw = jit_train_step(make_train_step(model, t.optimizer, rt_plan),
                                  donate=donate)

        def init_fn():
            return init_train_state(model, jax.random.PRNGKey(t.data.seed),
                                    t.optimizer, rt_plan)

        def step_fn(state, step):
            return step_raw(state, _device_batch(stream.batch_at(step)))

        record = ctx.record.stage_view(self.name)
        ckpt = Checkpointer(f"{ctx.record.artifacts_dir}/ckpt-{self.name}",
                            keep=2)
        shardings = self._restore_shardings(ctx, ckpt, model, rt_plan,
                                            init_fn)
        env = ExecutionEnvelope(
            record, checkpointer=ckpt, checkpoint_every=t.checkpoint_every,
            failures=ctx.params.get("failures"),
        )
        state = env.run(init_state=init_fn, step_fn=step_fn,
                        num_steps=num_steps, state_shardings=shardings)
        return {self.state_key: state}

    def _restore_shardings(self, ctx, ckpt, model, rt_plan, init_fn):
        """When a committed checkpoint exists (stage retry or run
        resume) and the scheduler bound this stage to a placement,
        restore directly onto that placement's mesh — the elastic
        reshard path for a re-plan that landed on a different slice."""
        placement = ctx.current_placement() \
            if hasattr(ctx, "current_placement") else None
        if placement is None or ckpt.latest_step() is None:
            return None
        import jax

        from repro.ft.elastic import state_shardings

        try:
            like = jax.eval_shape(init_fn)
            mesh = placement.build_mesh()
            shardings = state_shardings(like, model, mesh, rt_plan)
        except Exception as e:  # placement is advisory — never block restore
            if ctx.record is not None:
                ctx.record.log_event("reshard_skipped", {
                    "stage": self.name, "error": repr(e)})
            return None
        if ctx.record is not None:
            ctx.record.log_event("reshard", {
                "stage": self.name, "slice": placement.slice_name,
                "mesh_shape": list(placement.mesh_shape)})
        return shardings


# ===========================================================================
# Serve
# ===========================================================================
class ServeStage(Stage):
    """Batched-serving smoke through the ServeEngine.

    The engine mode and chunking are knobs: constructor args, overridable
    per run via the ``serve_engine`` / ``serve_chunk`` context params
    (the CLI's ``--serve-engine`` / ``--serve-chunk``).  ``fused`` is the
    on-device batched-sampling fast path; ``legacy`` keeps the per-slot
    host-sampling baseline around for A/B runs; ``paged`` serves from
    the paged KV pool (prefix sharing, HBM proportional to live
    tokens — see docs/serving.md).  ``serve_spec_k`` / ``serve_draft``
    (the CLI's ``--serve-spec-k`` / ``--serve-draft``) turn on lossless
    speculative decoding: k drafts per verify round from the n-gram
    proposer, or from a reduced draft model named by arch."""

    inputs = ("cfg",)
    outputs = ("final_state", "completions")
    placement_key = "__main__"
    cache_params = ("serve_engine", "serve_chunk", "serve_spec_k",
                    "serve_draft", "smoke_batch", "smoke_seq")

    def __init__(self, name: str = "serve", engine: str = "fused",
                 decode_chunk: int = 1):
        super().__init__(name)
        self.engine = engine
        self.decode_chunk = decode_chunk

    def run(self, ctx: StageContext) -> Dict[str, Any]:
        import jax

        from repro.models import build_model
        from repro.serve.engine import smoke_serve

        t = ctx.template
        cfg = ctx.get("cfg")
        smoke_batch = ctx.params.get("smoke_batch", 4)
        smoke_seq = ctx.params.get("smoke_seq", 32)
        engine = ctx.params.get("serve_engine", self.engine)
        decode_chunk = ctx.params.get("serve_chunk", self.decode_chunk)
        spec_k = ctx.params.get("serve_spec_k", 0)
        draft_arch = ctx.params.get("serve_draft", "")
        model = build_model(cfg)
        params, _ = model.init(jax.random.PRNGKey(t.data.seed))
        draft = draft_params = None
        if draft_arch:
            from repro.configs import get_config, reduced
            draft = build_model(reduced(get_config(draft_arch)))
            draft_params, _ = draft.init(jax.random.PRNGKey(t.data.seed + 1))
        completions, stats = smoke_serve(
            model, params, num_requests=smoke_batch * 2,
            max_batch=smoke_batch, max_seq=smoke_seq + 64,
            vocab_size=cfg.vocab_size, seed=t.data.seed,
            engine=engine, decode_chunk=decode_chunk,
            spec_k=spec_k, draft=draft, draft_params=draft_params,
        )
        if ctx.record is not None:
            ctx.record.stage_view(self.name).log(0, stats)
        return {"final_state": completions, "completions": completions}


# ===========================================================================
# Explore
# ===========================================================================
class ExploreStage(Stage):
    """Run a cost-performance sweep (:func:`repro.core.explore.explore`)
    as a workflow stage.

    The spec comes from the constructor or the ``explore_spec`` context
    param (the latter wins, which is how a fan-out graph sweeps several
    grids over one template).  When the run has a
    :class:`~repro.core.stagecache.StageCache` attached, every grid
    *cell* is cached under its own content-addressed key (cell
    coordinates + constraints + catalog generation), so a re-run or a
    resumed sweep recomputes only cells the catalog change actually
    invalidated.  The rendered Markdown report lands in the run's
    artifacts dir as ``explore.md`` and an ``explore`` provenance event
    records the headline numbers.
    """

    outputs = ("explore_result", "explore_report")
    cache_params = ("explore_spec",)

    def __init__(self, name: str = "explore", spec: Any = None,
                 report_name: str = "explore.md"):
        super().__init__(name)
        self.spec = spec
        self.report_name = report_name

    def spec_config(self) -> Dict[str, Any]:
        """Serialize the nested ExploreSpec by field instead of letting
        the base class emit an ``__opaque__`` marker for it."""
        cfg = super().spec_config()
        cfg["spec"] = (dataclasses.asdict(self.spec)
                       if self.spec is not None else None)
        return cfg

    @classmethod
    def from_spec_config(cls, name: str, config: Dict[str, Any]) -> "ExploreStage":
        from repro.core.explore import ExploreSpec

        config = dict(config)
        spec = config.pop("spec", None)
        if spec is not None:
            spec = ExploreSpec(**spec)  # __post_init__ re-tuples the axes
        return cls(name, spec=spec, **config)

    def signature(self) -> Dict[str, Any]:
        """Fold the constructor spec and the catalog generation into the
        stage identity: the base signature() keeps only primitive attrs,
        which would let a resume skip restore a *different* spec's
        result — and a catalog that gained a slice type must miss the
        resume/cache hash so the sweep re-plans."""
        from repro.core import calibrate
        from repro.core.catalog import catalog_generation

        sig = super().signature()
        sig["spec"] = (dataclasses.asdict(self.spec)
                       if self.spec is not None else None)
        sig["catalog_generation"] = catalog_generation()
        # an activated calibration re-scores every cell, so the resume
        # hash must miss when the active coefficient set changes
        sig["calibration_generation"] = calibrate.active_generation()
        return sig

    def run(self, ctx: StageContext) -> Dict[str, Any]:
        import json

        from repro.core.explore import explore, report_markdown, result_doc

        spec = ctx.params.get("explore_spec", self.spec)
        if spec is None:
            raise ValueError(
                f"ExploreStage {self.name!r} needs an ExploreSpec (pass "
                f"spec= to the constructor or explore_spec in ctx.params)")
        result = explore(spec, cache=ctx.cache)
        report = report_markdown(result)
        if ctx.record is not None:
            path = f"{ctx.record.artifacts_dir}/{self.report_name}"
            with open(path, "w", encoding="utf-8") as f:
                f.write(report)
            doc_path = path.rsplit(".", 1)[0] + ".json"
            with open(doc_path, "w", encoding="utf-8") as f:
                json.dump(result_doc(result), f, indent=2, sort_keys=True)
            ctx.record.log_event("explore", {
                "stage": self.name,
                "cells": len(result.cells),
                "feasible_cells": result.feasible_cells,
                "cells_from_cache": result.cells_from_cache,
                "frontier_size": len(result.frontier),
                "catalog_generation": result.catalog_generation,
                "report": path,
            })
        return {"explore_result": result, "explore_report": report}


# ===========================================================================
# Calibrate
# ===========================================================================
class CalibrateStage(Stage):
    """Harvest this run's telemetry into the calibration store and refit
    the cost model (:mod:`repro.core.calibrate`).

    Placed after a workload stage, it pairs the manifest's planned
    roofline terms with the measured step times (``harvest_run``),
    optionally folds in other finished runs (``runs_root``) and bench
    result files (``bench_paths``), ingests everything into the
    flocked :class:`~repro.core.calibrate.CalibrationStore`, refits the
    per-(chip, kind) coefficients, and reports drift.  With
    ``activate=True`` the fresh fit becomes the process-wide active
    calibration — subsequent plans (and their memo keys) pick it up
    immediately.

    Deliberately uncacheable: its job is absorbing *new* telemetry; a
    cache hit would silently drop this run's samples.
    """

    outputs = ("calibration", "drift_report")

    def __init__(self, name: str = "calibrate",
                 store_path: Optional[str] = None,
                 runs_root: Optional[str] = None,
                 bench_paths: Tuple[str, ...] = (),
                 min_samples: int = 4,
                 drift_threshold: float = 0.25,
                 activate: bool = False):
        super().__init__(name)
        self.store_path = store_path
        self.runs_root = runs_root
        self.bench_paths = tuple(bench_paths)
        self.min_samples = int(min_samples)
        self.drift_threshold = float(drift_threshold)
        self.activate = bool(activate)

    def run(self, ctx: StageContext) -> Dict[str, Any]:
        from repro.core import calibrate

        samples: List[Any] = []
        if ctx.record is not None:
            samples.extend(calibrate.harvest_run(ctx.record))
        if self.runs_root:
            samples.extend(calibrate.harvest_runs_dir(self.runs_root))
        for path in self.bench_paths:
            samples.extend(calibrate.harvest_bench(path))

        store = calibrate.CalibrationStore(self.store_path)
        added = store.ingest(samples)
        cal = store.fit(min_samples=self.min_samples)
        drift = store.drift(threshold=self.drift_threshold,
                            calibration=cal)
        if self.activate:
            calibrate.activate(cal)

        if ctx.record is not None:
            lines = [f"# Calibration (generation {cal.generation})", ""]
            lines.append(f"- samples harvested: {len(samples)} "
                         f"({added} new)")
            for c in cal.cells:
                lines.append(
                    f"- {c.chip}/{c.kind}: mode={c.mode} "
                    f"a_c={c.a_compute:.4f} a_m={c.a_memory:.4f} "
                    f"a_x={c.a_collective:.4f} b={c.intercept:.2e} "
                    f"scale={c.scale:.4f} n={c.n_samples} "
                    f"resid={c.residual:.3e}")
            lines += ["", "## Drift", "", drift.summary(), ""]
            path = f"{ctx.record.artifacts_dir}/calibration.md"
            with open(path, "w", encoding="utf-8") as f:
                f.write("\n".join(lines))
            ctx.record.log_event("calibrate", {
                "stage": self.name,
                "samples": len(samples),
                "new_samples": added,
                "cells": len(cal.cells),
                "generation": cal.generation,
                "drifted": len(drift.drifted),
                "activated": self.activate,
                "report": path,
            })
        return {"calibration": cal, "drift_report": drift}


# ===========================================================================
# Move
# ===========================================================================
class MoveStage(Stage):
    """Explicit cross-backend data movement for one context key.

    Inserted (by hand, or by :func:`repro.core.check.insert_movement_stages`)
    between a producer and a consumer the planner bound to *different*
    slices, where the implicit shared-blackboard handoff would hide a
    real transfer.  In this single-process harness the blackboard already
    holds the value, so the stage's job is to make the movement a
    first-class, observable step: it verifies the key is present,
    emits a ``data_move`` provenance event with a structural size
    summary, and acts as an ordering barrier (consumers are rewired to
    depend on it).  It declares no outputs — the key stays owned by its
    producer, so inserting a move can never trip the duplicate-producer
    validation.
    """

    def __init__(self, name: str, key: str = "", src: str = "", dst: str = ""):
        super().__init__(name)
        self.key = key
        self.src = src
        self.dst = dst
        self.inputs = (key,) if key else ()

    def run(self, ctx: StageContext) -> Dict[str, Any]:
        from repro.core.graph import _describe

        value = ctx.get(self.key)
        if ctx.record is not None:
            ctx.record.log_event("data_move", {
                "stage": self.name, "key": self.key,
                "src": self.src, "dst": self.dst,
                "value": _describe(value),
            })
        return {}


# ===========================================================================
# Eval
# ===========================================================================
class EvalStage(Stage):
    """Held-out loss of a trained state on freshly-seeded batches."""

    inputs = ("cfg", "shape")
    # a pure function of (cfg, shape, state): eligible for process
    # dispatch so a CPU-bound eval fan-out escapes the GIL.  The body
    # does small jax compute — see docs/executors.md for the fork
    # caveat; unpicklable state falls back inline automatically.
    process_safe = True

    def __init__(self, name: str = "eval", state_key: str = "final_state",
                 num_batches: int = 2, seed_offset: int = 10_000,
                 loss_key: Optional[str] = None):
        super().__init__(name)
        self.state_key = state_key
        self.num_batches = num_batches
        self.seed_offset = seed_offset
        self.loss_key = loss_key or f"eval_loss.{name}"
        self.outputs = (self.loss_key,)

    def run(self, ctx: StageContext) -> Dict[str, Any]:
        from repro.data import make_stream
        from repro.models import build_model

        t = ctx.template
        cfg = ctx.get("cfg")
        shape = ctx.get("shape")
        state = ctx.get(self.state_key)
        model = build_model(cfg)
        dcfg = dataclasses.replace(t.data, seed=t.data.seed + self.seed_offset)
        stream = make_stream(cfg, shape, dcfg)
        losses = []
        for i in range(self.num_batches):
            loss, _ = model.loss(state["params"],
                                 _device_batch(stream.batch_at(i)))
            losses.append(float(loss))
        mean = float(np.mean(losses)) if losses else float("nan")
        if ctx.record is not None:
            ctx.record.log_event("eval", {"stage": self.name,
                                          "loss": mean,
                                          "num_batches": self.num_batches})
        return {self.loss_key: mean}


# ===========================================================================
# Validate & visualize
# ===========================================================================
class ValidateStage(Stage):
    """Run the template's checks over the metric history.

    ``source`` limits the history to one stage's rows (for sweeps);
    by default all metric rows count, matching the monolithic runner.
    """

    outputs = ("checks",)

    def __init__(self, name: str = "validate",
                 source: Optional[str] = None,
                 checks: Optional[Tuple[str, ...]] = None):
        super().__init__(name)
        self.source = source
        self.checks = checks

    def run(self, ctx: StageContext) -> Dict[str, Any]:
        _require_record(ctx, self, "checks read the metric history back")
        t = ctx.template
        history = ctx.record.metrics()
        if self.source is not None:
            history = [h for h in history if h.get("stage") == self.source]
        names = self.checks if self.checks is not None else t.checks
        checks: Dict[str, Tuple[bool, str]] = {}
        for name in names:
            checks[name] = CHECKS[name](history)
            ctx.record.log_event("check", {
                "name": name, "ok": checks[name][0],
                "detail": checks[name][1],
            })
        return {"checks": checks}


class VisualizeStage(Stage):
    """Loss-curve artifact (one line per stage when several trained)."""

    def __init__(self, name: str = "visualize", filename: str = "loss.png"):
        super().__init__(name)
        self.filename = filename

    def run(self, ctx: StageContext) -> Dict[str, Any]:
        _require_record(ctx, self, "plots read metrics and write artifacts")
        record = ctx.record
        history = record.metrics()
        if not history:
            return {}
        try:
            import matplotlib
            matplotlib.use("Agg")
            import matplotlib.pyplot as plt
        except ImportError:  # pragma: no cover
            return {}
        by_stage: Dict[str, Tuple[List, List]] = {}
        for h in history:
            if "loss" not in h:
                continue
            key = str(h.get("stage", "train"))
            xs, ys = by_stage.setdefault(key, ([], []))
            xs.append(h["step"])
            ys.append(h["loss"])
        if not by_stage:
            return {}
        fig, ax = plt.subplots(figsize=(6, 3.5))
        for key, (xs, ys) in sorted(by_stage.items()):
            ax.plot(xs, ys, lw=1.5,
                    label=key if len(by_stage) > 1 else None)
        ax.set_xlabel("step")
        ax.set_ylabel("loss")
        ax.set_title(record.manifest.get("template", "run"))
        if len(by_stage) > 1:
            ax.legend(fontsize=8)
        ax.grid(alpha=0.3)
        fig.tight_layout()
        path = f"{record.artifacts_dir}/{self.filename}"
        fig.savefig(path, dpi=110)
        plt.close(fig)
        return {"loss_plot": path}
