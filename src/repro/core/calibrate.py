"""Telemetry-calibrated cost model: closing the loop from measured runs
back into the planner (ROADMAP "Close the loop").

The roofline constants in :mod:`repro.core.costmodel` are static priors.
HPCAdvisor-style advice needs the opposite direction too: harvest what
actually happened — per-step ``step_time_s`` rows from provenance
metrics, per-device flops/bytes from :func:`repro.launch.hlo_stats.
analyze_hlo`, replayed ``BENCH_*.json`` telemetry — and regress the
model onto it.

The unit of calibration is the **(chip, kind) cell** (e.g. ``("v5e",
"train")``).  Each observed sample pairs the three analytic roofline
terms the static model computed for a placement with the step time that
placement actually measured:

    measured_step_s ≈ a_c·compute_s + a_m·memory_s + a_x·collective_s + b

Fitting those four coefficients per cell is ordinary (weighted) least
squares, which makes calibration *exactly recoverable*: telemetry
generated from known coefficients fits back to them to float precision
(the property test in tests/test_calibrate.py).  Cells with too few
samples for a full regression fall back to a single multiplicative
correction on the static roofline combine (``mode="scale"``).

Pieces
------
* :class:`Sample` / harvesters — :func:`harvest_run` (provenance
  metrics + the plan doc's recorded terms), :func:`sample_from_hlo`
  (analyze_hlo output × a chip spec), :func:`harvest_bench`
  (``calibration_samples`` sections of BENCH_*.json files).
* :class:`CalibrationStore` — persistent JSON store of samples +
  fitted cells, atomic-rename writes under an fcntl flock with
  merge-on-flush (the :class:`repro.core.stagecache.RunManifest`
  pattern), so concurrent writers lose no samples.  Every mutation
  bumps a monotonic store generation.
* :class:`Calibration` / :func:`activate` — the fitted coefficient set
  the cost model consults: :func:`repro.core.costmodel.estimate` and
  ``estimate_batch`` both apply the active calibration's per-(chip,
  kind) prediction, so the scalar/vectorized parity oracle is
  preserved.  The planner salts its memo entries with
  :func:`calibration_state`, a per-*kind* fingerprint of the active
  coefficients — activating new coefficients for ``("v5e", "train")``
  invalidates memoized train plans while decode/prefill intents stay
  memoized (tests assert via ``PLANNER_STATS``/``SCORING_STATS``).
* :func:`check_drift` — flags cells whose predictions diverged from
  the stored telemetry past a relative-error threshold: the signal to
  re-fit (or to distrust a provider's published specs).

See docs/calibration.md for the store format and the drift semantics.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import threading
from typing import Any, Dict, Iterable, List, Mapping, Optional, Tuple

import numpy as np

from repro.core.catalog import CHIPS, ChipSpec
from repro.core.stagecache import _atomic_write, _FileLock

STORE_VERSION = 1
DEFAULT_STORE_PATH = ".repro_cache/calibration.json"

# prediction floor: a pathological fit must never hand the planner a
# zero/negative step time (ranking and $/token divide by it)
_STEP_FLOOR = 1e-12


def default_store_path() -> str:
    return os.environ.get("REPRO_CALIBRATION_PATH", DEFAULT_STORE_PATH)


def _digest(obj: Any) -> str:
    payload = json.dumps(obj, sort_keys=True, default=str)
    return hashlib.sha256(payload.encode()).hexdigest()[:16]


def static_step(compute_s, memory_s, collective_s):
    """The uncalibrated roofline combine (elementwise on arrays):
    dominant term plus a 15% tax on the overlapped remainder — kept in
    lockstep with :func:`repro.core.costmodel.estimate`."""
    peak = np.maximum(np.maximum(compute_s, memory_s), collective_s)
    return peak + 0.15 * (compute_s + memory_s + collective_s - peak)


# ===========================================================================
# Samples: one observed (terms, measured step) pair
# ===========================================================================
@dataclasses.dataclass(frozen=True)
class Sample:
    """One telemetry observation for a (chip, kind) cell: the analytic
    roofline terms the model computed for the placement, paired with the
    step time the placement actually measured."""

    chip: str
    kind: str
    compute_s: float
    memory_s: float
    collective_s: float
    measured_step_s: float
    source: str = ""
    weight: float = 1.0

    def key(self) -> str:
        return _digest(dataclasses.asdict(self))

    def to_doc(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_doc(cls, doc: Mapping[str, Any]) -> "Sample":
        return cls(**{f.name: doc[f.name] for f in dataclasses.fields(cls)
                      if f.name in doc})


def sample_from_estimate(est: Any, chip: str, kind: str,
                         measured_step_s: float, *, source: str = "",
                         weight: float = 1.0) -> Sample:
    """Pair a :class:`~repro.core.costmodel.CostEstimate`'s terms with a
    measured step time."""
    return Sample(chip=chip, kind=kind,
                  compute_s=float(est.compute_s),
                  memory_s=float(est.memory_s),
                  collective_s=float(est.collective_s),
                  measured_step_s=float(measured_step_s),
                  source=source, weight=float(weight))


def sample_from_hlo(stats: Mapping[str, float], chip, kind: str,
                    measured_step_s: float, *, source: str = "",
                    weight: float = 1.0) -> Sample:
    """Build a sample from :func:`repro.launch.hlo_stats.analyze_hlo`
    output (per-device flops / hbm_bytes / total_collective_bytes) and a
    chip spec (a :class:`~repro.core.catalog.ChipSpec` or a name in
    ``CHIPS``)."""
    spec = CHIPS[chip] if isinstance(chip, str) else chip
    return Sample(
        chip=spec.name, kind=kind,
        compute_s=float(stats.get("flops", 0.0)) / spec.peak_bf16_flops,
        memory_s=float(stats.get("hbm_bytes", 0.0)) / spec.hbm_bw,
        collective_s=(float(stats.get("total_collective_bytes", 0.0))
                      / spec.ici_bw),
        measured_step_s=float(measured_step_s),
        source=source, weight=float(weight),
    )


def harvest_run(record: Any, *, skip_steps: int = 1) -> List[Sample]:
    """Harvest one provenance run: the plan doc's recorded roofline
    terms (written by PlanStage) paired with the median measured
    ``step_time_s`` from the run's metric rows.  The first ``skip_steps``
    timed rows are dropped (they absorb compilation).  Returns ``[]``
    when the run carries no plan terms or no timed steps — harvesting is
    best-effort, never an error."""
    plan_doc = (record.manifest or {}).get("plan") or {}
    needed = ("chip", "kind", "compute_s", "memory_s", "collective_s")
    if any(plan_doc.get(k) is None for k in needed):
        return []
    times = [float(r["step_time_s"]) for r in record.metrics()
             if isinstance(r.get("step_time_s"), (int, float))]
    times = times[skip_steps:]
    if not times:
        return []
    return [Sample(
        chip=str(plan_doc["chip"]), kind=str(plan_doc["kind"]),
        compute_s=float(plan_doc["compute_s"]),
        memory_s=float(plan_doc["memory_s"]),
        collective_s=float(plan_doc["collective_s"]),
        measured_step_s=float(np.median(np.asarray(times))),
        source=f"run:{record.run_id}",
        weight=float(len(times)),
    )]


def harvest_runs_dir(root: str) -> List[Sample]:
    """Harvest every run under a provenance root (``repro calibrate
    --runs-dir``)."""
    from repro.core.provenance import ProvenanceStore

    if not os.path.isdir(root):
        return []
    store = ProvenanceStore(root)
    out: List[Sample] = []
    for run_id in store.list_runs():
        try:
            out.extend(harvest_run(store.load(run_id)))
        except (OSError, ValueError, KeyError, json.JSONDecodeError):
            continue
    return out


def harvest_bench(path: str) -> List[Sample]:
    """Harvest a ``BENCH_*.json`` file: any section carrying a
    ``calibration_samples`` list of sample docs contributes (the
    planner bench's calibration section writes one)."""
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError):
        return []
    out: List[Sample] = []

    def walk(node):
        if isinstance(node, dict):
            rows = node.get("calibration_samples")
            if isinstance(rows, list):
                for row in rows:
                    try:
                        out.append(Sample.from_doc(row))
                    except (TypeError, KeyError):
                        continue
            for v in node.values():
                walk(v)

    walk(doc)
    return out


# ===========================================================================
# Fitted coefficients
# ===========================================================================
@dataclasses.dataclass(frozen=True)
class CellCalibration:
    """Fitted coefficients for one (chip, kind) cell.

    ``mode="linear"`` predicts ``a_c·compute + a_m·memory +
    a_x·collective + b`` (the least-squares fit); ``mode="scale"`` is
    the low-sample fallback: one multiplicative correction on the
    static roofline combine."""

    chip: str
    kind: str
    a_compute: float = 1.0
    a_memory: float = 1.0
    a_collective: float = 1.0
    intercept: float = 0.0
    mode: str = "linear"
    scale: float = 1.0
    n_samples: int = 0
    residual: float = 0.0  # rms relative error of the fit on its samples

    def predict(self, compute_s, memory_s, collective_s):
        """Calibrated step seconds; elementwise on arrays, and
        bit-identical between the scalar and batched cost-model paths
        (both call exactly this)."""
        if self.mode == "scale":
            pred = self.scale * static_step(compute_s, memory_s,
                                            collective_s)
        else:
            pred = (self.a_compute * compute_s + self.a_memory * memory_s
                    + self.a_collective * collective_s + self.intercept)
        return np.maximum(pred, _STEP_FLOOR)

    def to_doc(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_doc(cls, doc: Mapping[str, Any]) -> "CellCalibration":
        return cls(**{f.name: doc[f.name] for f in dataclasses.fields(cls)
                      if f.name in doc})


@dataclasses.dataclass(frozen=True)
class Calibration:
    """An immutable set of fitted cells, keyed ``(chip, kind)``.

    ``generation`` is the store generation the set was fitted at —
    reports and provenance events cite it.  ``kind_state(kind)`` is the
    planner's memo salt: a stable fingerprint of every cell touching
    one workload kind, so activating new train coefficients invalidates
    memoized train plans while decode intents keep their memo hits."""

    cells: Tuple[CellCalibration, ...] = ()
    generation: int = 0

    def __post_init__(self):
        by_key = {(c.chip, c.kind): c for c in self.cells}
        object.__setattr__(self, "_by_key", by_key)
        states: Dict[str, str] = {}
        for kind in sorted({c.kind for c in self.cells}):
            states[kind] = _digest(sorted(
                (c.chip, c.to_doc()) for c in self.cells if c.kind == kind))
        object.__setattr__(self, "_kind_states", states)

    def cell(self, chip: str, kind: str) -> Optional[CellCalibration]:
        return self._by_key.get((chip, kind))

    def for_kind(self, kind: str) -> Dict[str, CellCalibration]:
        return {c.chip: c for c in self.cells if c.kind == kind}

    def kind_state(self, kind: str) -> str:
        return self._kind_states.get(kind, "")


def fit_cells(samples: Iterable[Sample], *,
              min_samples: int = 4) -> List[CellCalibration]:
    """Weighted least squares per (chip, kind) group.

    Groups with at least ``min_samples`` observations and full column
    rank get the 4-coefficient linear fit (which *exactly* recovers
    coefficients from noise-free synthetic telemetry); smaller or
    degenerate groups fall back to the single-scale correction."""
    groups: Dict[Tuple[str, str], List[Sample]] = {}
    for s in samples:
        groups.setdefault((s.chip, s.kind), []).append(s)
    out: List[CellCalibration] = []
    for (chip, kind), rows in sorted(groups.items()):
        c = np.asarray([r.compute_s for r in rows], dtype=np.float64)
        m = np.asarray([r.memory_s for r in rows], dtype=np.float64)
        x = np.asarray([r.collective_s for r in rows], dtype=np.float64)
        y = np.asarray([r.measured_step_s for r in rows], dtype=np.float64)
        w = np.sqrt(np.maximum(
            np.asarray([r.weight for r in rows], dtype=np.float64), 0.0))
        cell: Optional[CellCalibration] = None
        if len(rows) >= min_samples:
            design = np.stack([c, m, x, np.ones_like(c)], axis=1)
            coef, _, rank, _ = np.linalg.lstsq(design * w[:, None],
                                               y * w, rcond=None)
            if rank == design.shape[1]:
                cell = CellCalibration(
                    chip=chip, kind=kind,
                    a_compute=float(coef[0]), a_memory=float(coef[1]),
                    a_collective=float(coef[2]), intercept=float(coef[3]),
                    mode="linear", n_samples=len(rows))
        if cell is None:
            base = static_step(c, m, x)
            ratio = np.where(base > 0, y / np.maximum(base, _STEP_FLOOR), 1.0)
            ws = w * w
            scale = float(np.sum(ratio * ws) / max(np.sum(ws), _STEP_FLOOR))
            cell = CellCalibration(chip=chip, kind=kind, mode="scale",
                                   scale=scale, n_samples=len(rows))
        pred = cell.predict(c, m, x)
        rel = (pred - y) / np.maximum(np.abs(y), _STEP_FLOOR)
        cell = dataclasses.replace(
            cell, residual=float(np.sqrt(np.mean(rel * rel))))
        out.append(cell)
    return out


# ===========================================================================
# Drift detection
# ===========================================================================
@dataclasses.dataclass(frozen=True)
class DriftCell:
    chip: str
    kind: str
    n_samples: int
    mean_rel_err: float
    max_rel_err: float
    drifted: bool


@dataclasses.dataclass(frozen=True)
class DriftReport:
    """Per-cell predicted-vs-measured divergence.  A cell is *drifted*
    when its mean relative error exceeds the threshold — the signal to
    re-fit the calibration (or to distrust the catalog's specs for that
    chip)."""

    threshold: float
    cells: Tuple[DriftCell, ...]

    @property
    def drifted(self) -> Tuple[DriftCell, ...]:
        return tuple(c for c in self.cells if c.drifted)

    def summary(self) -> str:
        if not self.cells:
            return "no telemetry to check"
        bits = []
        for c in self.cells:
            flag = "DRIFT" if c.drifted else "ok"
            bits.append(f"{c.chip}/{c.kind}: mean {c.mean_rel_err * 100:.1f}% "
                        f"max {c.max_rel_err * 100:.1f}% "
                        f"over {c.n_samples} samples [{flag}]")
        return "; ".join(bits)


def check_drift(samples: Iterable[Sample],
                calibration: Optional[Calibration] = None, *,
                threshold: float = 0.25) -> DriftReport:
    """Compare each sample's measured step time against the prediction —
    the calibration's cell when one covers the sample, the static
    roofline prior otherwise — and flag cells past ``threshold`` mean
    relative error."""
    groups: Dict[Tuple[str, str], List[Sample]] = {}
    for s in samples:
        groups.setdefault((s.chip, s.kind), []).append(s)
    cells: List[DriftCell] = []
    for (chip, kind), rows in sorted(groups.items()):
        c = np.asarray([r.compute_s for r in rows], dtype=np.float64)
        m = np.asarray([r.memory_s for r in rows], dtype=np.float64)
        x = np.asarray([r.collective_s for r in rows], dtype=np.float64)
        y = np.asarray([r.measured_step_s for r in rows], dtype=np.float64)
        cell = calibration.cell(chip, kind) if calibration else None
        pred = (cell.predict(c, m, x) if cell is not None
                else static_step(c, m, x))
        rel = np.abs(pred - y) / np.maximum(np.abs(y), _STEP_FLOOR)
        mean = float(np.mean(rel))
        cells.append(DriftCell(chip=chip, kind=kind, n_samples=len(rows),
                               mean_rel_err=mean,
                               max_rel_err=float(np.max(rel)),
                               drifted=mean > threshold))
    return DriftReport(threshold=threshold, cells=tuple(cells))


# ===========================================================================
# The persistent store
# ===========================================================================
class CalibrationStore:
    """Persistent JSON store of telemetry samples + fitted cells.

    One file (default ``.repro_cache/calibration.json``, or
    ``$REPRO_CALIBRATION_PATH``)::

        {"version": 1, "generation": N,
         "samples": {<sample key>: <sample doc>, ...},
         "cells":   {"<chip>|<kind>": <cell doc>, ...}}

    Writes follow the :class:`~repro.core.stagecache.RunManifest`
    discipline: every read-modify-write runs under an fcntl
    :class:`~repro.core.stagecache._FileLock` on a sidecar sentinel,
    merges the on-disk state with this writer's delta, and lands via
    atomic temp-file + rename — so concurrent ingesting processes lose
    no samples (the hammer test).  ``generation`` is monotonic and
    bumps on every mutation; the planner's memo salt and explore cache
    keys derive from it through the *active* calibration."""

    def __init__(self, path: Optional[str] = None):
        self.path = path or default_store_path()
        self.lock_path = self.path + ".lock"
        parent = os.path.dirname(self.path)
        if parent:
            os.makedirs(parent, exist_ok=True)
        self._lock = threading.Lock()

    # -- raw document ---------------------------------------------------
    def _read_disk(self) -> Dict[str, Any]:
        try:
            with open(self.path) as f:
                doc = json.load(f)
        except (OSError, json.JSONDecodeError):
            doc = None
        if not isinstance(doc, dict) or doc.get("version") != STORE_VERSION:
            return {"version": STORE_VERSION, "generation": 0,
                    "samples": {}, "cells": {}}
        doc.setdefault("generation", 0)
        doc.setdefault("samples", {})
        doc.setdefault("cells", {})
        return doc

    def _write_disk(self, doc: Dict[str, Any]) -> bool:
        payload = json.dumps(doc, indent=1, sort_keys=True).encode()
        parent = os.path.dirname(self.path) or "."
        return _atomic_write(parent, self.path, payload)

    def document(self) -> Dict[str, Any]:
        """A read-only snapshot of the raw store document."""
        with self._lock:
            with _FileLock(self.lock_path):
                return self._read_disk()

    def generation(self) -> int:
        return int(self.document().get("generation", 0))

    # -- mutation (merge-on-flush under the flock) ----------------------
    def ingest(self, samples: Iterable[Sample]) -> int:
        """Merge samples into the store (deduplicated by content hash).
        Returns the number of *new* samples; bumps the generation iff
        anything changed."""
        new = {s.key(): s.to_doc() for s in samples}
        if not new:
            return 0
        with self._lock:
            with _FileLock(self.lock_path):
                doc = self._read_disk()
                before = len(doc["samples"])
                doc["samples"].update(new)
                added = len(doc["samples"]) - before
                if added:
                    doc["generation"] = int(doc["generation"]) + 1
                    self._write_disk(doc)
        return added

    def fit(self, *, min_samples: int = 4) -> Calibration:
        """Re-fit every (chip, kind) cell from the stored samples,
        persist the coefficients, bump the generation, and return the
        fitted :class:`Calibration`."""
        with self._lock:
            with _FileLock(self.lock_path):
                doc = self._read_disk()
                samples = [Sample.from_doc(d)
                           for d in doc["samples"].values()]
                cells = fit_cells(samples, min_samples=min_samples)
                doc["cells"] = {f"{c.chip}|{c.kind}": c.to_doc()
                                for c in cells}
                doc["generation"] = int(doc["generation"]) + 1
                self._write_disk(doc)
                return Calibration(cells=tuple(cells),
                                   generation=int(doc["generation"]))

    def clear(self) -> None:
        with self._lock:
            with _FileLock(self.lock_path):
                doc = self._read_disk()
                doc["samples"] = {}
                doc["cells"] = {}
                doc["generation"] = int(doc["generation"]) + 1
                self._write_disk(doc)

    # -- read views -----------------------------------------------------
    def samples(self, chip: Optional[str] = None,
                kind: Optional[str] = None) -> List[Sample]:
        out = [Sample.from_doc(d)
               for d in self.document()["samples"].values()]
        if chip is not None:
            out = [s for s in out if s.chip == chip]
        if kind is not None:
            out = [s for s in out if s.kind == kind]
        out.sort(key=lambda s: s.key())
        return out

    def calibration(self) -> Calibration:
        """The stored fitted cells (empty Calibration when never
        fitted)."""
        doc = self.document()
        cells = tuple(sorted(
            (CellCalibration.from_doc(d) for d in doc["cells"].values()),
            key=lambda c: (c.chip, c.kind)))
        return Calibration(cells=cells, generation=int(doc["generation"]))

    def drift(self, *, threshold: float = 0.25,
              calibration: Optional[Calibration] = None) -> DriftReport:
        """Drift of the stored (or given) calibration against the stored
        telemetry."""
        doc = self.document()
        samples = [Sample.from_doc(d) for d in doc["samples"].values()]
        if calibration is None:
            cells = tuple(CellCalibration.from_doc(d)
                          for d in doc["cells"].values())
            calibration = Calibration(cells=cells,
                                      generation=int(doc["generation"]))
        return check_drift(samples, calibration, threshold=threshold)


# ===========================================================================
# The active calibration — what the cost model consults
# ===========================================================================
_ACTIVE_LOCK = threading.Lock()
_ACTIVE: Optional[Calibration] = None
_ACTIVE_GEN = 0  # bumps on every activate/deactivate (memo salt)


def activate(calibration: Calibration) -> Calibration:
    """Install a calibration as the one ``estimate``/``estimate_batch``
    apply.  Bumps the activation generation, so planner memo entries and
    explore cell keys salted with :func:`calibration_state` go stale for
    exactly the kinds whose coefficients changed."""
    global _ACTIVE, _ACTIVE_GEN
    with _ACTIVE_LOCK:
        _ACTIVE = calibration
        _ACTIVE_GEN += 1
    return calibration


def deactivate() -> None:
    """Back to the static priors (tests, and ``repro calibrate
    --deactivate``)."""
    global _ACTIVE, _ACTIVE_GEN
    with _ACTIVE_LOCK:
        _ACTIVE = None
        _ACTIVE_GEN += 1


def active() -> Optional[Calibration]:
    return _ACTIVE


def active_generation() -> int:
    """Monotonic activation counter (stage signatures fold this in so a
    resume can't restore a plan computed under different coefficients)."""
    return _ACTIVE_GEN


def active_cell(chip: str, kind: str) -> Optional[CellCalibration]:
    """The active coefficients for one (chip, kind), or None — the
    scalar cost model's per-estimate lookup."""
    cal = _ACTIVE
    return cal.cell(chip, kind) if cal is not None else None


def active_for_kind(kind: str) -> Dict[str, CellCalibration]:
    """{chip: coefficients} of the active calibration for one workload
    kind — the batched cost model's per-table lookup."""
    cal = _ACTIVE
    return cal.for_kind(kind) if cal is not None else {}


def calibration_state(kind: str) -> str:
    """The planner's memo salt for one workload kind: "" under static
    priors, else a stable fingerprint of the active coefficients
    touching that kind.  Two intents of different kinds therefore
    invalidate independently."""
    cal = _ACTIVE
    return cal.kind_state(kind) if cal is not None else ""
