"""Analytic roofline cost model: the planner's scoring function.

For a (model config × shape × slice × plan) cell it estimates the three
roofline terms the assignment defines —

    compute    = FLOPs / (chips × peak)
    memory     = HBM bytes / (chips × hbm_bw)
    collective = collective bytes / (chips × link_bw)

plus per-device memory occupancy (feasibility) and $ cost.  The dry-run
later *verifies* these against the compiled HLO (cost_analysis /
memory_analysis / collective parse) — the planner must be cheap because it
scores hundreds of candidates per intent, the compiler is the ground
truth for the chosen one.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Tuple

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core import calibrate
from repro.core.catalog import CandidateTable, SliceType


@dataclasses.dataclass(frozen=True)
class PlanGeometry:
    """The parallel geometry the planner scores (mirror of parallel.Plan,
    decoupled so the cost model has no jax dependency)."""

    data: int = 1
    model: int = 1
    pods: int = 1
    fsdp: bool = True
    remat: str = "full"  # none | dots | full
    microbatch: int = 1
    compress_grads: bool = False

    @property
    def total(self) -> int:
        return self.data * self.model * self.pods

    @property
    def dp_total(self) -> int:
        return self.data * self.pods


@dataclasses.dataclass
class CostEstimate:
    compute_s: float
    memory_s: float
    collective_s: float
    step_s: float
    bytes_per_device: float
    hbm_frac: float
    cost_per_step: float
    cost_per_mtok: float  # $ per million tokens
    bottleneck: str
    feasible: bool
    detail: Dict[str, float]


BYTES = {"bfloat16": 2, "float32": 4, "int8": 1}


def _train_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """fwd+bwd FLOPs per step (model = 6·N_active·tokens + attention)."""
    tokens = shape.tokens_per_step
    base = 6.0 * cfg.active_param_count() * tokens
    # attention scores+values: fwd 4·B·S²·H·Dh (causal ÷2), bwd ×2
    S, B = shape.seq_len, shape.global_batch
    if cfg.family in ("ssm",):
        attn = 0.0
    else:
        w = cfg.sliding_window or S
        eff = min(S, w)
        attn = 3.0 * 4.0 * B * S * eff * cfg.num_heads * cfg.head_dim * 0.5
        attn *= cfg.num_layers
    return base + attn


def _decode_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    B = shape.global_batch
    base = 2.0 * cfg.active_param_count() * B
    S = shape.seq_len
    if cfg.family == "ssm":
        attn = 0.0
    else:
        w = cfg.sliding_window or S
        ctx_local = min(S, w)
        n_global = len(cfg.global_attn_layers) if cfg.global_attn_layers else 0
        n_local = cfg.num_layers - n_global
        ctx = n_local * ctx_local + n_global * S if n_global else cfg.num_layers * ctx_local
        attn = 4.0 * B * ctx * cfg.num_heads * cfg.head_dim
    return base + attn


def _prefill_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    t = _train_flops(cfg, shape)
    return t / 3.0  # fwd only


def state_bytes(cfg: ModelConfig, geom: PlanGeometry, kind: str,
                moment_dtype: str = "float32") -> float:
    """Global bytes of persistent state (params + opt for train; params
    for serve)."""
    n = cfg.param_count()
    pb = n * BYTES["float32"]  # master params f32
    if kind != "train":
        return n * BYTES[cfg.dtype]
    mb = 2 * n * BYTES[moment_dtype]
    return pb + mb


def kv_cache_bytes(cfg: ModelConfig, shape: ShapeConfig) -> float:
    B, S = shape.global_batch, shape.seq_len
    bt = BYTES[cfg.dtype]
    if cfg.family == "ssm":
        d_in = 2 * cfg.d_model
        dh = d_in // cfg.num_heads
        return cfg.num_layers * B * cfg.num_heads * dh * dh * 4.0
    per_layer_full = 2 * B * S * cfg.num_kv_heads * cfg.head_dim * bt
    if cfg.family == "hybrid" and cfg.sliding_window:
        W = min(cfg.sliding_window, S)
        n_global = len(cfg.global_attn_layers)
        n_local = cfg.num_layers - n_global
        per_layer_win = 2 * B * W * cfg.num_kv_heads * cfg.head_dim * bt
        ssm = cfg.num_layers * B * (2 * cfg.d_model) * cfg.ssm_state * 4.0
        return n_global * per_layer_full + n_local * per_layer_win + ssm
    total = cfg.num_layers * per_layer_full
    if cfg.is_encoder_decoder:
        total += 2 * cfg.num_layers * B * cfg.encoder_frames * cfg.num_kv_heads * cfg.head_dim * bt
    return total


def activation_bytes(cfg: ModelConfig, shape: ShapeConfig, geom: PlanGeometry) -> float:
    """Live activation bytes per device during train fwd+bwd (remat-aware,
    per-microbatch)."""
    if shape.kind != "train":
        B, S = shape.global_batch, shape.seq_len
        if shape.kind == "decode":
            S = 1
        return B * S * cfg.d_model * BYTES[cfg.dtype] * 8 / geom.total
    B = shape.global_batch / max(geom.dp_total, 1) / max(geom.microbatch, 1)
    S = shape.seq_len
    bt = BYTES[cfg.dtype]
    d = cfg.d_model
    if geom.remat == "full":
        per_layer = B * S * d * bt  # only the block input is saved
        live = cfg.num_layers * per_layer + 4 * B * S * d * bt
    elif geom.remat == "dots":
        per_layer = 3 * B * S * d * bt
        live = cfg.num_layers * per_layer + 4 * B * S * d * bt
    else:
        ff = max(cfg.d_ff, d * 2)
        per_layer = (6 * d + 2 * ff) * B * S * bt / max(geom.model, 1) * 1.0
        live = cfg.num_layers * per_layer
    # logits are the spike for big-vocab models
    logits = B * S * cfg.vocab_size * 4.0 / max(geom.model, 1)
    return live / max(geom.model, 1) + logits


def collective_bytes(cfg: ModelConfig, shape: ShapeConfig, geom: PlanGeometry,
                     kind: str) -> Dict[str, float]:
    """Per-step global collective traffic by category (bytes summed over
    devices, ring-algorithm convention: volume ≈ 2·payload·(n-1)/n ≈ 2·payload)."""
    bt = BYTES[cfg.dtype]
    n = cfg.param_count()
    out: Dict[str, float] = {"tp_allreduce": 0.0, "dp_gradreduce": 0.0,
                             "fsdp_gather": 0.0, "ep_alltoall": 0.0,
                             "pod_gradreduce": 0.0}
    tokens = shape.tokens_per_step
    act = tokens * cfg.d_model * bt
    if geom.model > 1:
        # 2 allreduce per block fwd (attn out + mlp out), x3 for bwd
        nblocks = cfg.num_layers + (cfg.encoder_layers if cfg.is_encoder_decoder else 0)
        mult = 3.0 if kind == "train" else 1.0
        out["tp_allreduce"] = 2.0 * act * 2 * nblocks * mult
    if kind == "train":
        grad_bytes = n * BYTES["float32"]
        if geom.fsdp:
            # params all-gather fwd+bwd, grads reduce-scatter
            out["fsdp_gather"] = 2 * n * bt + grad_bytes
        if geom.dp_total > 1 and not geom.fsdp:
            out["dp_gradreduce"] = 2 * grad_bytes
        if geom.pods > 1:
            pod_bytes = 2 * grad_bytes / max(geom.data * geom.model, 1)
            if geom.compress_grads:
                pod_bytes /= 4.0  # int8 + scales
            out["pod_gradreduce"] = pod_bytes
    if cfg.num_experts > 0:
        disp = tokens * cfg.top_k * cfg.moe_capacity_factor * cfg.d_model * bt
        mult = 3.0 if kind == "train" else 1.0
        out["ep_alltoall"] = 2.0 * disp * cfg.num_layers * mult / max(1, 1)
    return out


def estimate(cfg: ModelConfig, shape: ShapeConfig, slice_: SliceType,
             geom: PlanGeometry, moment_dtype: str = "float32") -> CostEstimate:
    chip = slice_.chip
    chips = geom.total
    kind = shape.kind

    if kind == "train":
        flops = _train_flops(cfg, shape)
    elif kind == "prefill":
        flops = _prefill_flops(cfg, shape)
    else:
        flops = _decode_flops(cfg, shape)
    compute_s = flops / (chips * chip.peak_bf16_flops)

    # HBM traffic: weights stream once per microbatch (+opt update r/w in
    # train), activations once, kv cache read per decode step
    sbytes = state_bytes(cfg, geom, kind, moment_dtype)
    act = activation_bytes(cfg, shape, geom)
    if kind == "train":
        hbm = sbytes * 3.0 * geom.microbatch + act * chips
    elif kind == "prefill":
        hbm = cfg.param_count() * BYTES[cfg.dtype] + act * chips
    else:
        hbm = cfg.param_count() * BYTES[cfg.dtype] + kv_cache_bytes(cfg, shape)
    memory_s = hbm / (chips * chip.hbm_bw)

    colls = collective_bytes(cfg, shape, geom, kind)
    intra = sum(v for k, v in colls.items() if k != "pod_gradreduce")
    inter = colls["pod_gradreduce"]
    collective_s = intra / (chips * chip.ici_bw) + (
        inter / (chips * chip.dci_bw) if inter else 0.0
    )
    # latency floor: ring collectives cost ~2(n-1) hops regardless of size.
    # This is what makes over-provisioning small workloads lose — the real
    # phenomenon behind the paper's Table 2 efficiency collapse.
    HOP_ICI, HOP_DCI = 1e-6, 10e-6
    nblocks = cfg.num_layers + (cfg.encoder_layers if cfg.is_encoder_decoder else 0)
    n_ops = 0.0
    if geom.model > 1:
        n_ops += 4.0 * nblocks * (3.0 if kind == "train" else 1.0)
    if kind == "train" and (geom.fsdp or geom.dp_total > 1):
        n_ops += 2.0 * nblocks
    if cfg.num_experts > 0:
        n_ops += 2.0 * cfg.num_layers * (3.0 if kind == "train" else 1.0)
    ring = max(geom.data * geom.model, 2)
    collective_s += n_ops * 2 * (ring - 1) * HOP_ICI / max(geom.microbatch, 1) ** 0
    if geom.pods > 1 and kind == "train":
        collective_s += 2 * (geom.pods - 1) * HOP_DCI * 2 * nblocks

    # per-device occupancy
    dev_state = sbytes / chips
    dev_cache = kv_cache_bytes(cfg, shape) / chips if kind != "train" else 0.0
    dev_grads = cfg.param_count() * 4.0 / chips if kind == "train" else 0.0
    dev_act = act
    bytes_per_device = dev_state + dev_cache + dev_grads + dev_act
    hbm_frac = bytes_per_device / chip.hbm_bytes

    # roofline combine: dominant term with 15% tax for imperfect overlap;
    # when a calibration is active for this (chip, kind), its fitted
    # prediction replaces the combine (estimate_batch applies the exact
    # same CellCalibration.predict, preserving scalar/batch parity)
    cal = calibrate.active_cell(chip.name, kind)
    if cal is not None:
        step_s = float(cal.predict(compute_s, memory_s, collective_s))
    else:
        step_s = max(compute_s, memory_s, collective_s)
        step_s = step_s + 0.15 * (compute_s + memory_s + collective_s - step_s)

    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    price_s = slice_.chip.price_per_hour * chips / 3600.0
    cost_per_step = price_s * step_s
    tokens = shape.tokens_per_step
    cost_per_mtok = cost_per_step / max(tokens, 1) * 1e6
    feasible = hbm_frac <= 0.92 and chips == slice_.total_chips

    return CostEstimate(
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        step_s=step_s,
        bytes_per_device=bytes_per_device,
        hbm_frac=hbm_frac,
        cost_per_step=cost_per_step,
        cost_per_mtok=cost_per_mtok,
        bottleneck=bottleneck,
        feasible=feasible,
        detail={**terms, **colls, "flops": flops, "hbm_bytes": hbm},
    )


# ===========================================================================
# Batched estimation over a CandidateTable — the vectorized planner hot
# path.  The scalar `estimate()` above stays the parity oracle: every
# formula here mirrors it operation-for-operation on whole float64
# columns, so the two agree bit-for-bit per cell.
# ===========================================================================
BOTTLENECK_NAMES = ("compute", "memory", "collective")


@dataclasses.dataclass(frozen=True)
class BatchEstimate:
    """Columnar CostEstimate: one float64 entry per CandidateTable row."""

    compute_s: np.ndarray
    memory_s: np.ndarray
    collective_s: np.ndarray
    step_s: np.ndarray
    bytes_per_device: np.ndarray
    hbm_frac: np.ndarray
    cost_per_step: np.ndarray
    cost_per_mtok: np.ndarray
    bottleneck_code: np.ndarray  # index into BOTTLENECK_NAMES
    feasible: np.ndarray         # bool
    colls: Dict[str, np.ndarray]
    flops: float
    hbm: np.ndarray

    def __len__(self) -> int:
        return len(self.step_s)

    def estimate_at(self, i: int) -> CostEstimate:
        """Materialize one row as the scalar CostEstimate `estimate()`
        would have returned for the same cell."""
        terms = {
            "compute": float(self.compute_s[i]),
            "memory": float(self.memory_s[i]),
            "collective": float(self.collective_s[i]),
        }
        return CostEstimate(
            compute_s=terms["compute"],
            memory_s=terms["memory"],
            collective_s=terms["collective"],
            step_s=float(self.step_s[i]),
            bytes_per_device=float(self.bytes_per_device[i]),
            hbm_frac=float(self.hbm_frac[i]),
            cost_per_step=float(self.cost_per_step[i]),
            cost_per_mtok=float(self.cost_per_mtok[i]),
            bottleneck=BOTTLENECK_NAMES[int(self.bottleneck_code[i])],
            feasible=bool(self.feasible[i]),
            detail={**terms,
                    **{k: float(v[i]) for k, v in self.colls.items()},
                    "flops": self.flops, "hbm_bytes": float(self.hbm[i])},
        )


def _activation_bytes_batch(cfg: ModelConfig, shape: ShapeConfig,
                            table: CandidateTable) -> np.ndarray:
    if shape.kind != "train":
        B, S = shape.global_batch, shape.seq_len
        if shape.kind == "decode":
            S = 1
        return B * S * cfg.d_model * BYTES[cfg.dtype] * 8 / table.chips
    dp_total = np.maximum(table.data * table.pods, 1)
    B = shape.global_batch / dp_total / np.maximum(table.microbatch, 1)
    S = shape.seq_len
    bt = BYTES[cfg.dtype]
    d = cfg.d_model
    model = np.maximum(table.model, 1)
    live_full = cfg.num_layers * (B * S * d * bt) + 4 * B * S * d * bt
    live_dots = cfg.num_layers * (3 * B * S * d * bt) + 4 * B * S * d * bt
    ff = max(cfg.d_ff, d * 2)
    live_none = cfg.num_layers * ((6 * d + 2 * ff) * B * S * bt / model * 1.0)
    live = np.where(table.remat_code == 2, live_full,
                    np.where(table.remat_code == 1, live_dots, live_none))
    logits = B * S * cfg.vocab_size * 4.0 / model
    return live / model + logits


def _collective_bytes_batch(cfg: ModelConfig, shape: ShapeConfig,
                            table: CandidateTable,
                            kind: str) -> Dict[str, np.ndarray]:
    bt = BYTES[cfg.dtype]
    n = cfg.param_count()
    z = np.zeros(len(table))
    out = {"tp_allreduce": z, "dp_gradreduce": z, "fsdp_gather": z,
           "ep_alltoall": z, "pod_gradreduce": z}
    tokens = shape.tokens_per_step
    act = tokens * cfg.d_model * bt
    nblocks = cfg.num_layers + (cfg.encoder_layers if cfg.is_encoder_decoder else 0)
    mult = 3.0 if kind == "train" else 1.0
    out["tp_allreduce"] = np.where(table.model > 1,
                                   2.0 * act * 2 * nblocks * mult, 0.0)
    if kind == "train":
        grad_bytes = n * BYTES["float32"]
        out["fsdp_gather"] = np.where(table.fsdp, 2 * n * bt + grad_bytes, 0.0)
        out["dp_gradreduce"] = np.where(
            (table.data * table.pods > 1) & ~table.fsdp, 2 * grad_bytes, 0.0)
        pod_bytes = 2 * grad_bytes / np.maximum(table.data * table.model, 1)
        pod_bytes = np.where(table.compress, pod_bytes / 4.0, pod_bytes)
        out["pod_gradreduce"] = np.where(table.pods > 1, pod_bytes, 0.0)
    if cfg.num_experts > 0:
        disp = tokens * cfg.top_k * cfg.moe_capacity_factor * cfg.d_model * bt
        out["ep_alltoall"] = np.full(
            len(table), 2.0 * disp * cfg.num_layers * mult / max(1, 1))
    return out


# Instrumentation: how much scoring work the process has done.  The
# incremental re-planning tests assert on these — adding a slice type to
# the catalog must re-score only the new rows, so ``rows_scored`` is the
# observable that proves memoized intents were extended, not rebuilt.
SCORING_STATS: Dict[str, int] = {"batch_calls": 0, "rows_scored": 0}


def reset_scoring_stats() -> None:
    SCORING_STATS["batch_calls"] = 0
    SCORING_STATS["rows_scored"] = 0


def estimate_batch(cfg: ModelConfig, shape: ShapeConfig,
                   table: CandidateTable,
                   moment_dtype: str = "float32") -> BatchEstimate:
    """`estimate()` over every row of a CandidateTable at once."""
    SCORING_STATS["batch_calls"] += 1
    SCORING_STATS["rows_scored"] += len(table)
    kind = shape.kind
    if kind == "train":
        flops = _train_flops(cfg, shape)
    elif kind == "prefill":
        flops = _prefill_flops(cfg, shape)
    else:
        flops = _decode_flops(cfg, shape)
    compute_s = flops / (table.chips * table.peak_flops)

    sbytes = state_bytes(cfg, PlanGeometry(), kind, moment_dtype)
    act = _activation_bytes_batch(cfg, shape, table)
    if kind == "train":
        hbm = sbytes * 3.0 * table.microbatch + act * table.chips
    elif kind == "prefill":
        hbm = cfg.param_count() * BYTES[cfg.dtype] + act * table.chips
    else:
        hbm = np.broadcast_to(np.float64(
            cfg.param_count() * BYTES[cfg.dtype] + kv_cache_bytes(cfg, shape)
        ), (len(table),))
    memory_s = hbm / (table.chips * table.hbm_bw)

    colls = _collective_bytes_batch(cfg, shape, table, kind)
    intra = (colls["tp_allreduce"] + colls["dp_gradreduce"]
             + colls["fsdp_gather"] + colls["ep_alltoall"])
    inter = colls["pod_gradreduce"]
    collective_s = intra / (table.chips * table.ici_bw) + np.where(
        inter != 0, inter / (table.chips * table.dci_bw), 0.0)
    HOP_ICI, HOP_DCI = 1e-6, 10e-6
    nblocks = cfg.num_layers + (cfg.encoder_layers if cfg.is_encoder_decoder else 0)
    kmult = 3.0 if kind == "train" else 1.0
    n_ops = np.zeros(len(table))
    n_ops = n_ops + np.where(table.model > 1, 4.0 * nblocks * kmult, 0.0)
    if kind == "train":
        n_ops = n_ops + np.where(table.fsdp | (table.data * table.pods > 1),
                                 2.0 * nblocks, 0.0)
    if cfg.num_experts > 0:
        n_ops = n_ops + 2.0 * cfg.num_layers * kmult
    ring = np.maximum(table.data * table.model, 2)
    collective_s = collective_s + n_ops * 2 * (ring - 1) * HOP_ICI
    if kind == "train":
        collective_s = collective_s + np.where(
            table.pods > 1, 2 * (table.pods - 1) * HOP_DCI * 2 * nblocks, 0.0)

    dev_state = sbytes / table.chips
    dev_cache = (kv_cache_bytes(cfg, shape) / table.chips
                 if kind != "train" else 0.0)
    dev_grads = (cfg.param_count() * 4.0 / table.chips
                 if kind == "train" else 0.0)
    bytes_per_device = dev_state + dev_cache + dev_grads + act
    hbm_frac = bytes_per_device / table.hbm_bytes

    peak = np.maximum(np.maximum(compute_s, memory_s), collective_s)
    step_s = peak + 0.15 * (compute_s + memory_s + collective_s - peak)
    cal_map = calibrate.active_for_kind(kind)
    if cal_map:
        # per-row calibrated override, chip by chip — the same
        # CellCalibration.predict the scalar oracle applies, elementwise
        chip_names = np.asarray([s.chip.name for s in table.slices])
        for name in sorted(cal_map):
            row_mask = chip_names == name
            if row_mask.any():
                pred = cal_map[name].predict(compute_s, memory_s,
                                             collective_s)
                step_s = np.where(row_mask, pred, step_s)
    bottleneck_code = np.argmax(
        np.stack([compute_s, memory_s, collective_s]), axis=0)
    price_s = table.chip_price * table.chips / 3600.0
    cost_per_step = price_s * step_s
    tokens = shape.tokens_per_step
    cost_per_mtok = cost_per_step / max(tokens, 1) * 1e6
    # chips == slice.total_chips holds by construction (mesh shapes always
    # multiply out to the slice size), so feasibility is the HBM gate alone
    feasible = hbm_frac <= 0.92

    return BatchEstimate(
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        step_s=step_s, bytes_per_device=bytes_per_device, hbm_frac=hbm_frac,
        cost_per_step=cost_per_step, cost_per_mtok=cost_per_mtok,
        bottleneck_code=bottleneck_code, feasible=feasible,
        colls=colls, flops=flops, hbm=np.asarray(hbm, dtype=np.float64),
    )


def concat_batches(a: BatchEstimate, b: BatchEstimate) -> BatchEstimate:
    """Row-wise concatenation of two BatchEstimates over the same
    workload — how a memoized scored table absorbs the rows a catalog
    extension added without re-scoring its prefix."""
    def cat(x, y):
        return np.concatenate([np.atleast_1d(np.asarray(x)),
                               np.atleast_1d(np.asarray(y))])

    return BatchEstimate(
        compute_s=cat(a.compute_s, b.compute_s),
        memory_s=cat(a.memory_s, b.memory_s),
        collective_s=cat(a.collective_s, b.collective_s),
        step_s=cat(a.step_s, b.step_s),
        bytes_per_device=cat(a.bytes_per_device, b.bytes_per_device),
        hbm_frac=cat(a.hbm_frac, b.hbm_frac),
        cost_per_step=cat(a.cost_per_step, b.cost_per_step),
        cost_per_mtok=cat(a.cost_per_mtok, b.cost_per_mtok),
        bottleneck_code=cat(a.bottleneck_code, b.bottleneck_code),
        feasible=cat(a.feasible, b.feasible).astype(bool),
        colls={k: cat(a.colls[k], b.colls[k]) for k in a.colls},
        flops=a.flops,
        hbm=cat(a.hbm, b.hbm),
    )


# ===========================================================================
# Retry-aware expected cost — folding preemption rates and restart
# backoff budgets into a plan's $ projection (docs/cost-model.md has the
# derivation; tests assert monotonicity in the failure rate).
# ===========================================================================
@dataclasses.dataclass(frozen=True)
class RetryCost:
    """Expected-cost projection for a run under preemptions + restarts.

    ``expected_cost_usd`` is the billed projection (failure-free cost
    plus re-done work); ``expected_hours`` is the wall-clock projection
    (billed hours plus restart backoff, which is waited but not billed —
    the slice is gone while we back off)."""

    base_cost_usd: float        # failure-free: steps × cost_per_step
    expected_cost_usd: float    # base + expected re-done work
    expected_cost_per_mtok: float
    run_hours: float            # failure-free duration
    expected_hours: float       # run + wasted + backoff (wall clock)
    expected_failures: float    # Poisson mean, capped at max_restarts
    backoff_s: float            # expected total restart backoff
    failure_rate_per_hour: float  # slice-level rate (per-chip rate × chips)


def retry_expected_cost(est: CostEstimate, slice_: SliceType, steps: int,
                        preempt_rate_per_chip_hour: float = 0.0,
                        policy=None,
                        restore_frac: float = 0.5) -> RetryCost:
    """Fold a preemption rate and a :class:`~repro.ft.failures.RestartPolicy`
    into a plan's cost projection.

    Model: preemptions arrive Poisson at ``rate × total_chips`` per hour
    (bigger slices expose more failure domains), so a run of
    failure-free duration ``T`` expects ``E = min(λ·T, max_restarts)``
    failures.  The ``E`` failures split the run into ``E + 1`` segments;
    with checkpoint-restart, each failure re-does ``restore_frac`` of
    its segment on average, so the expected wasted (and billed) time is
    ``E/(E+1) · restore_frac · T`` — bounded by ``restore_frac · T``
    however unreliable the fleet gets.  Backoff between restarts
    (:meth:`RestartPolicy.expected_total_backoff_s`) extends the wall
    clock but is not billed.  Every term is monotone non-decreasing in
    the preemption rate.
    """
    run_hours = steps * est.step_s / 3600.0
    base_cost = steps * est.cost_per_step
    lam = preempt_rate_per_chip_hour * slice_.total_chips
    expected_failures = lam * run_hours
    if policy is not None:
        expected_failures = min(expected_failures,
                                float(policy.max_restarts))
    waste_frac = (expected_failures / (expected_failures + 1.0)
                  * restore_frac)
    wasted_hours = waste_frac * run_hours
    billed_hours = run_hours + wasted_hours
    expected_cost = base_cost * (1.0 + waste_frac)
    backoff_s = (policy.expected_total_backoff_s(expected_failures)
                 if policy is not None else 0.0)
    scale = expected_cost / base_cost if base_cost > 0 else 1.0
    return RetryCost(
        base_cost_usd=base_cost,
        expected_cost_usd=expected_cost,
        expected_cost_per_mtok=est.cost_per_mtok * scale,
        run_hours=run_hours,
        expected_hours=billed_hours + backoff_s / 3600.0,
        expected_failures=expected_failures,
        backoff_s=backoff_s,
        failure_rate_per_hour=lam,
    )
