"""The paper's primary contribution: the Adviser platform core —
workflow stage graphs and templates, intent-based planning over a
resource catalog, roofline cost model, provenance, budgets and the
execution envelope."""
from repro.core.budget import BudgetExceeded, BudgetLedger, PermissionDenied, Workspace
from repro.core.catalog import CATALOG, CHIPS, SliceType, build_catalog, catalog_summary, find_slice
from repro.core.costmodel import CostEstimate, PlanGeometry, estimate
from repro.core.envelope import ExecutionEnvelope
from repro.core.graph import (
    CycleError,
    FnStage,
    GraphError,
    MissingInputError,
    Stage,
    StageContext,
    StageGraph,
    StageResult,
)
from repro.core.intent import ResourceIntent
from repro.core.planner import (
    PlanChoice,
    enumerate_plans,
    plan,
    plan_stages,
    rank,
    to_runtime_plan,
)
from repro.core.provenance import (
    ProvenanceStore,
    RunRecord,
    StageRecordView,
    capture_environment,
    stable_hash,
)
from repro.core.stages import (
    CHECKS,
    DataStage,
    EvalStage,
    PlanStage,
    ServeStage,
    TrainStage,
    ValidateStage,
    VisualizeStage,
)
from repro.core.workflow import (
    REGISTRY,
    WorkflowRegistry,
    WorkflowResult,
    WorkflowTemplate,
    compile_template,
    run_workflow,
)

__all__ = [
    "BudgetExceeded", "BudgetLedger", "PermissionDenied", "Workspace",
    "CATALOG", "CHIPS", "SliceType", "build_catalog", "catalog_summary", "find_slice",
    "CostEstimate", "PlanGeometry", "estimate",
    "ExecutionEnvelope", "ResourceIntent",
    "CycleError", "FnStage", "GraphError", "MissingInputError",
    "Stage", "StageContext", "StageGraph", "StageResult",
    "PlanChoice", "enumerate_plans", "plan", "plan_stages", "rank", "to_runtime_plan",
    "ProvenanceStore", "RunRecord", "StageRecordView",
    "capture_environment", "stable_hash",
    "CHECKS", "DataStage", "EvalStage", "PlanStage", "ServeStage",
    "TrainStage", "ValidateStage", "VisualizeStage",
    "REGISTRY", "WorkflowRegistry", "WorkflowResult",
    "WorkflowTemplate", "compile_template", "run_workflow",
]
