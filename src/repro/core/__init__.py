"""The paper's primary contribution: the Adviser platform core —
workflow templates, intent-based planning over a resource catalog,
roofline cost model, provenance, budgets and the execution envelope."""
from repro.core.budget import BudgetExceeded, BudgetLedger, PermissionDenied, Workspace
from repro.core.catalog import CATALOG, CHIPS, SliceType, build_catalog, catalog_summary, find_slice
from repro.core.costmodel import CostEstimate, PlanGeometry, estimate
from repro.core.envelope import ExecutionEnvelope
from repro.core.intent import ResourceIntent
from repro.core.planner import PlanChoice, enumerate_plans, plan, rank, to_runtime_plan
from repro.core.provenance import ProvenanceStore, RunRecord, capture_environment, stable_hash
from repro.core.workflow import (
    CHECKS,
    REGISTRY,
    WorkflowRegistry,
    WorkflowResult,
    WorkflowTemplate,
    run_workflow,
)

__all__ = [
    "BudgetExceeded", "BudgetLedger", "PermissionDenied", "Workspace",
    "CATALOG", "CHIPS", "SliceType", "build_catalog", "catalog_summary", "find_slice",
    "CostEstimate", "PlanGeometry", "estimate",
    "ExecutionEnvelope", "ResourceIntent",
    "PlanChoice", "enumerate_plans", "plan", "rank", "to_runtime_plan",
    "ProvenanceStore", "RunRecord", "capture_environment", "stable_hash",
    "CHECKS", "REGISTRY", "WorkflowRegistry", "WorkflowResult",
    "WorkflowTemplate", "run_workflow",
]
