"""The paper's primary contribution: the Adviser platform core —
workflow stage graphs and templates, intent-based planning over a
resource catalog, roofline cost model, provenance, budgets and the
execution envelope."""
from repro.core.budget import BudgetExceeded, BudgetLedger, PermissionDenied, Workspace
from repro.core.calibrate import (
    Calibration,
    CalibrationStore,
    CellCalibration,
    DriftReport,
    Sample,
    check_drift,
    fit_cells,
    harvest_bench,
    harvest_run,
    harvest_runs_dir,
)
from repro.core.calibrate import activate as activate_calibration
from repro.core.calibrate import deactivate as deactivate_calibration
from repro.core.catalog import (
    CATALOG,
    CHIPS,
    CandidateTable,
    SliceType,
    build_catalog,
    candidate_table,
    catalog_generation,
    catalog_summary,
    find_slice,
    register_slice,
    unregister_slice,
)
from repro.core.costmodel import (
    BatchEstimate,
    CostEstimate,
    PlanGeometry,
    RetryCost,
    estimate,
    estimate_batch,
    retry_expected_cost,
)
from repro.core.envelope import ExecutionEnvelope
from repro.core.executor import (
    EXECUTOR_KINDS,
    Executor,
    LocalPoolExecutor,
    ThreadedExecutor,
    WorkerQueueExecutor,
    make_executor,
)
from repro.core.explore import (
    CellSpec,
    ExploreResult,
    ExploreSpec,
    FrontierPoint,
    compare_markdown,
    explore,
    report_markdown,
    result_doc,
)
from repro.core.graph import (
    CycleError,
    FnStage,
    GraphError,
    MissingInputError,
    Placement,
    Stage,
    StageContext,
    StageGraph,
    StageResult,
)
from repro.core.intent import ResourceIntent
from repro.core.planner import (
    PlanChoice,
    clear_planner_cache,
    enumerate_plans,
    intent_hash,
    plan,
    plan_stages,
    prune_dominated,
    rank,
    to_runtime_plan,
)
from repro.core.check import (
    CODES,
    CheckError,
    CheckReport,
    Diagnostic,
    check_spec,
    check_workflow,
    insert_movement_stages,
)
from repro.core.spec import (
    SPEC_VERSION,
    DeclaredStage,
    SpecError,
    dump_spec,
    dumps_spec,
    from_spec,
    load_spec,
    load_workflow,
    pack_template,
    register_stage_type,
    spec_for_template,
    to_spec,
    unpack_package,
    validate_spec,
)
from repro.core.runqueue import RunQueue, RunQueueClosed, RunTicket
from repro.core.stagecache import RunManifest, StageCache
from repro.core.provenance import (
    ProvenanceStore,
    RunRecord,
    StageRecordView,
    capture_environment,
    stable_hash,
)
from repro.core.registry import (
    PROVIDERS,
    ProviderProfile,
    ProviderRegistry,
    SliceOffer,
)
from repro.core.stages import (
    CHECKS,
    CalibrateStage,
    DataStage,
    EvalStage,
    ExploreStage,
    MoveStage,
    PlanStage,
    ServeStage,
    TrainStage,
    ValidateStage,
    VisualizeStage,
)
from repro.core.workflow import (
    REGISTRY,
    WorkflowRegistry,
    WorkflowResult,
    WorkflowTemplate,
    compile_template,
    resolve_placement_map,
    resolve_placements,
    run_workflow,
)
from repro.ft.failures import (
    FailureSchedule,
    InjectedFailure,
    RestartPolicy,
    WorkerLost,
)

__all__ = [
    "BudgetExceeded", "BudgetLedger", "PermissionDenied", "Workspace",
    "CATALOG", "CHIPS", "CandidateTable", "SliceType", "build_catalog",
    "candidate_table", "catalog_generation", "catalog_summary",
    "find_slice", "register_slice", "unregister_slice",
    "BatchEstimate", "CostEstimate", "PlanGeometry", "RetryCost",
    "estimate", "estimate_batch", "retry_expected_cost",
    "CellSpec", "ExploreResult", "ExploreSpec", "FrontierPoint",
    "explore", "report_markdown", "result_doc", "compare_markdown",
    "Calibration", "CalibrationStore", "CellCalibration", "DriftReport",
    "Sample", "check_drift", "fit_cells", "harvest_bench", "harvest_run",
    "harvest_runs_dir", "activate_calibration", "deactivate_calibration",
    "PROVIDERS", "ProviderProfile", "ProviderRegistry", "SliceOffer",
    "ExecutionEnvelope", "ResourceIntent",
    "CycleError", "FnStage", "GraphError", "MissingInputError", "Placement",
    "Stage", "StageCache", "StageContext", "StageGraph", "StageResult",
    "RunManifest",
    "EXECUTOR_KINDS", "Executor", "LocalPoolExecutor", "ThreadedExecutor",
    "WorkerQueueExecutor", "make_executor",
    "RunQueue", "RunQueueClosed", "RunTicket",
    "FailureSchedule", "InjectedFailure", "RestartPolicy", "WorkerLost",
    "PlanChoice", "clear_planner_cache", "enumerate_plans", "intent_hash",
    "plan", "plan_stages", "prune_dominated", "rank", "to_runtime_plan",
    "ProvenanceStore", "RunRecord", "StageRecordView",
    "capture_environment", "stable_hash",
    "CHECKS", "CalibrateStage", "DataStage", "EvalStage", "ExploreStage",
    "MoveStage", "PlanStage", "ServeStage", "TrainStage", "ValidateStage",
    "VisualizeStage",
    "REGISTRY", "WorkflowRegistry", "WorkflowResult",
    "WorkflowTemplate", "compile_template", "resolve_placement_map",
    "resolve_placements", "run_workflow",
    "SPEC_VERSION", "SpecError", "DeclaredStage", "register_stage_type",
    "to_spec", "from_spec", "validate_spec", "dumps_spec", "dump_spec",
    "load_spec", "load_workflow", "spec_for_template", "pack_template",
    "unpack_package",
    "CODES", "CheckError", "CheckReport", "Diagnostic", "check_spec",
    "check_workflow", "insert_movement_stages",
]
