"""Stage graph: the composable workflow DAG (paper §4.2 generalized).

A workflow is a directed acyclic graph of :class:`Stage` objects.  Each
stage declares the context keys it consumes (``inputs``) and produces
(``outputs``), an optional per-stage :class:`ResourceIntent` the planner
resolves independently (a cheap data-prep stage and an expensive train
stage can land on different slices), and a ``run(ctx)`` body.  The graph
executes stages in deterministic topological order, running independent
stages concurrently on a thread pool, and emits per-stage provenance
events (``stage_start`` / ``stage_end`` with timing and an outputs hash)
into the run's :class:`RunRecord`.

Resilience (see docs/architecture.md for the full event vocabulary):

  * **per-stage retry** — a stage failing with a *retryable* exception
    (default: :class:`~repro.ft.failures.InjectedFailure`, standing in
    for preemption/node loss) is re-run under a
    :class:`~repro.ft.failures.RestartPolicy` — per-stage ``retry``
    attribute, falling back to the graph-level policy passed to
    ``execute(retry=...)`` — with ``stage_failed`` / ``stage_retry``
    provenance events and capped exponential backoff between attempts;
  * **resume** — when ``ctx.resume`` carries a
    :class:`~repro.core.stagecache.RunManifest`, every completed stage's
    outputs are persisted under its content-addressed input hash, and a
    re-execution of the same run (``repro run --resume <run_id>``) skips
    stages whose recorded hash still matches, restoring their outputs;
  * **placement** — each stage is bound to its own resolved backend
    (its entry in ``stage_plans``, its own ``intent``, or the main
    workload's ``plan_choice`` when ``placement_key == "__main__"``),
    recorded as a ``placement`` provenance event and readable from the
    stage body via ``ctx.current_placement()``.

Graphs nest: ``inner.as_stage("prep")`` wraps a whole graph as a single
stage of an outer graph; nested stage events are name-prefixed
(``prep/tokenize``).

Authoring a custom stage (expanded guide: docs/authoring-stages.md)::

    class MyStage(Stage):
        inputs = ("cfg",)
        outputs = ("thing",)
        def run(self, ctx):
            return {"thing": make_thing(ctx.get("cfg"))}

    g = StageGraph("demo")
    g.add(DataStage())
    g.add(MyStage("mine"), depends_on=("data",))
    g.execute(StageContext(template=t, record=rec))
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from concurrent.futures import FIRST_COMPLETED, ThreadPoolExecutor, wait
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.intent import ResourceIntent
from repro.core.provenance import RunRecord, stable_hash
from repro.ft.failures import RestartPolicy


class GraphError(ValueError):
    """Structural problem in a stage graph (duplicate, unknown dep, cycle)."""


def _describe(v):
    """A *structural* summary of a value for hashing: arrays describe by
    dtype/shape (their repr would truncate content and force a device
    sync on multi-GB states), primitives by value, dataclasses by full
    field content, everything else by type name.  Hashes built from this
    detect wiring changes — different keys, shapes, scalar or config
    values — not bitwise array equality."""
    if v is None or isinstance(v, (bool, int, float, str)):
        return v
    shape = getattr(v, "shape", None)
    dtype = getattr(v, "dtype", None)
    if shape is not None and dtype is not None:
        return f"{dtype}{tuple(shape)}"
    if dataclasses.is_dataclass(v) and not isinstance(v, type):
        return {"__dataclass__": type(v).__name__,
                **{f.name: _describe(getattr(v, f.name))
                   for f in dataclasses.fields(v)}}
    if isinstance(v, dict):
        return {str(k): _describe(x)
                for k, x in sorted(v.items(), key=lambda kv: str(kv[0]))}
    if isinstance(v, (list, tuple)):
        return [_describe(x) for x in v]
    return type(v).__name__


def _describe_outputs(out: Dict[str, Any]) -> Dict[str, Any]:
    return {k: _describe(out[k]) for k in sorted(out)}


# attrs serialized at the spec *entry* level (ports, intent, policies) or
# not serializable at all (name is the entry key) — everything else in
# vars(stage) is constructor configuration and lands in the spec's
# ``config`` block (see repro.core.spec)
_SPEC_CONFIG_EXCLUDE = frozenset({
    "name", "inputs", "outputs", "intent", "retry", "checks",
    "placement_key", "resume_payload", "cacheable", "cache_params",
    "cache_template_fields", "cache_version", "unpicklable_outputs",
})


def _spec_value(v: Any) -> Any:
    """A JSON-able rendering of one constructor knob for the declarative
    spec.  Non-JSON-able values become an explicit ``{"__opaque__":
    <type>}`` marker instead of being dropped silently: the static
    checker flags opaque knobs on cacheable stages (they hash by type
    name only — see ADV008 in repro.core.check) and ``from_spec``
    refuses to reconstruct an executable stage from them."""
    if v is None or isinstance(v, (bool, int, float, str)):
        return v
    if isinstance(v, (list, tuple)):
        return [_spec_value(x) for x in v]
    if isinstance(v, dict):
        return {str(k): _spec_value(v[k])
                for k in sorted(v, key=str)}
    return {"__opaque__": type(v).__name__}


class CycleError(GraphError):
    pass


class MissingInputError(KeyError):
    """A stage asked the context for a key no upstream stage produced."""


# ===========================================================================
# Placement: the backend a stage is bound to
# ===========================================================================
@dataclasses.dataclass
class Placement:
    """The resolved backend one stage runs on.

    Derived from the stage's :class:`~repro.core.planner.PlanChoice` —
    slice (the catalog's backend unit), mesh shape/axes, chip count and
    price.  ``build_mesh()`` folds the planned mesh onto the locally
    visible devices (degenerate all-1s mesh on a CPU container, the real
    shape on a fleet) so stage bodies can place arrays on *their* backend
    rather than the global default.
    """

    stage: str
    slice_name: str
    mesh_shape: Tuple[int, ...]
    mesh_axes: Tuple[str, ...]
    chips: int
    price_per_hour: float
    summary: str = ""

    def as_doc(self) -> Dict[str, Any]:
        """JSON-able form for provenance events and CLI rendering."""
        return {
            "stage": self.stage,
            "slice": self.slice_name,
            "mesh_shape": list(self.mesh_shape),
            "mesh_axes": list(self.mesh_axes),
            "chips": self.chips,
            "price_per_hour": self.price_per_hour,
        }

    def render(self) -> str:
        mesh = "x".join(map(str, self.mesh_shape))
        return (f"{self.slice_name} mesh={mesh} chips={self.chips} "
                f"${self.price_per_hour:,.2f}/h")

    def build_mesh(self):
        """A jax Mesh for this placement, clamped to available devices."""
        from repro.launch.mesh import mesh_for_placement

        return mesh_for_placement(self.mesh_shape, self.mesh_axes)

    @classmethod
    def from_choice(cls, stage: str, choice: Any) -> "Placement":
        return cls(
            stage=stage,
            slice_name=choice.slice.name,
            mesh_shape=tuple(choice.mesh_shape),
            mesh_axes=tuple(choice.mesh_axes),
            chips=choice.slice.total_chips,
            price_per_hour=choice.slice.price_per_hour,
            summary=choice.summary,
        )


# ===========================================================================
# Stage & context
# ===========================================================================
class Stage:
    """One node of a workflow graph.

    Subclasses set ``name`` (unique within a graph), optionally declare
    ``inputs`` / ``outputs`` (context keys, used for validation and the
    CLI's DAG rendering), an ``intent`` (per-stage resource request the
    planner resolves via :func:`repro.core.planner.plan_stages`) and
    ``checks`` (names into the workflow CHECKS table), and implement
    ``run(ctx) -> dict`` returning the produced outputs.
    """

    name: str = "stage"
    inputs: Tuple[str, ...] = ()
    outputs: Tuple[str, ...] = ()
    intent: Optional[ResourceIntent] = None
    checks: Tuple[str, ...] = ()
    # -- executor dispatch ----------------------------------------------
    # False pins the body to the coordinator thread regardless of the
    # run's executor backend.  _SubworkflowStage opts out: its body *is*
    # a nested scheduler, and queueing it behind the very workers it
    # needs would deadlock the fleet.
    dispatchable: bool = True
    # True promises the body is a pure function of its picklable context
    # inputs — safe to marshal into a process-pool child (repro.core
    # .executor.LocalPoolExecutor).  Stages that touch live in-process
    # state (ledgers, jax engines, the run record) must stay False; they
    # run inline even under `--executor processes`.
    process_safe: bool = False
    # -- fault tolerance ------------------------------------------------
    # per-stage restart policy; None inherits the graph-level policy
    # passed to StageGraph.execute(retry=...).  Only exceptions matching
    # the policy's ``retry_on`` classes are retried.
    retry: Optional[RestartPolicy] = None
    # -- placement ------------------------------------------------------
    # how the scheduler binds this stage to a backend: "__main__" uses
    # the workflow's main plan_choice; None falls back to the stage's
    # entry in stage_plans, then to its own ``intent``.
    placement_key: Optional[str] = None
    # -- resume ---------------------------------------------------------
    # False = record this stage in the run manifest hash-only (no output
    # pickle): on resume it re-runs instead of restoring.  Set it on
    # stages with their own durable recovery path — TrainStage opts out
    # because its state is already committed by the checkpointer, and a
    # re-run restores the newest checkpoint without replaying steps.
    resume_payload: bool = True
    # -- cross-run caching (see repro.core.stagecache) ------------------
    # Only stages whose outputs are a pure function of the hashed inputs
    # should opt in; side-effectful stages (budget authorization, metric
    # logging, checkpoint writes) must stay uncacheable.
    cacheable: bool = False
    # ctx.params keys folded into the input hash (the knobs this stage
    # actually reads — keeps unrelated param changes from invalidating).
    # Also folded into the *resume* key, so uncacheable stages should
    # list their knobs too: it keeps `run --resume` from skipping a
    # stage whose effective configuration changed.
    cache_params: Tuple[str, ...] = ()
    # template fields folded into the input hash; None = whole template
    cache_template_fields: Optional[Tuple[str, ...]] = None
    # code-version salt: bump when the stage's implementation (or code it
    # calls into) changes output semantics, so stale entries can't hit
    cache_version: str = "1"
    # declared output keys whose values cannot be pickled (live handles,
    # jitted callables).  The run manifest / stage cache skip such
    # payloads at runtime; declaring them lets the static checker warn
    # *before* the run that resume/cache persistence will degrade
    # (ADV009 in repro.core.check).
    unpicklable_outputs: Tuple[str, ...] = ()

    def __init__(self, name: Optional[str] = None):
        if name is not None:
            self.name = name

    def run(self, ctx: "StageContext") -> Dict[str, Any]:
        raise NotImplementedError

    def resume_safe(self, ctx: "StageContext") -> bool:
        """May a resumed run skip this stage when its recorded input hash
        still matches?  Override to return False when skipping would
        bypass a side effect the run depends on — e.g. PlanStage refuses
        while a budget ledger is attached, so resume cannot dodge the
        authorization gate."""
        return True

    def signature(self) -> Dict[str, Any]:
        """JSON-able identity of this stage for the cache key: type,
        name, declared I/O, and its primitive constructor config."""
        cfg = {k: v for k, v in sorted(vars(self).items())
               if not k.startswith("_")
               and isinstance(v, (bool, int, float, str, tuple, list,
                                  dict, type(None)))}
        return {"type": type(self).__name__, "name": self.name,
                "version": self.cache_version,
                "inputs": list(self.inputs), "outputs": list(self.outputs),
                "config": _describe(cfg)}

    # -- declarative spec (see repro.core.spec) -------------------------
    def spec_config(self) -> Dict[str, Any]:
        """This stage's constructor configuration as a JSON-able dict —
        the ``config`` block of its spec entry.  Keys already serialized
        at the entry level (ports, intent, retry, cache knobs) are
        excluded; values that can't be rendered to JSON become
        ``{"__opaque__": <type>}`` markers (see :func:`_spec_value`).
        Override when ``vars(self)`` isn't the right inverse of
        ``__init__`` (e.g. ExploreStage's nested spec dataclass)."""
        return {k: _spec_value(v) for k, v in sorted(vars(self).items())
                if not k.startswith("_") and k not in _SPEC_CONFIG_EXCLUDE}

    @classmethod
    def from_spec_config(cls, name: str, config: Dict[str, Any]) -> "Stage":
        """Rebuild a stage from its spec entry's ``config`` block.  The
        default assumes ``config`` keys are constructor kwargs — true
        for every builtin stage; override alongside ``spec_config``."""
        return cls(name, **config)

    def __repr__(self):
        return f"<{type(self).__name__} {self.name!r}>"


class FnStage(Stage):
    """Wrap a plain callable ``fn(ctx) -> dict`` as a stage."""

    def __init__(self, name: str, fn: Callable[["StageContext"], Optional[Dict]],
                 inputs: Sequence[str] = (), outputs: Sequence[str] = (),
                 intent: Optional[ResourceIntent] = None,
                 retry: Optional[RestartPolicy] = None):
        super().__init__(name)
        self.fn = fn
        self.inputs = tuple(inputs)
        self.outputs = tuple(outputs)
        self.intent = intent
        self.retry = retry

    def run(self, ctx: "StageContext") -> Dict[str, Any]:
        return self.fn(ctx) or {}


@dataclasses.dataclass
class StageContext:
    """Shared state threaded through a graph execution.

    ``outputs`` is the blackboard stages read/write through ``get``/``put``
    (lock-guarded — stages may run concurrently); ``params`` carries
    run-scoped knobs (steps_override, smoke_batch, failures, intent);
    ``cache`` is an optional :class:`repro.core.stagecache.StageCache`
    the scheduler consults to skip cacheable stages across runs;
    ``resume`` is an optional
    :class:`repro.core.stagecache.RunManifest` recording completed
    stages of *this* run so an interrupted execution can be resumed.
    """

    template: Any = None
    record: Optional[RunRecord] = None
    store: Any = None
    ledger: Any = None
    user: str = "anonymous"
    workspace: str = "default"
    cache: Any = None
    resume: Any = None
    params: Dict[str, Any] = dataclasses.field(default_factory=dict)
    outputs: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        self._lock = threading.Lock()
        self._placements: Dict[str, Placement] = {}
        self._tls = threading.local()

    def get(self, key: str, default: Any = dataclasses.MISSING) -> Any:
        with self._lock:
            if key in self.outputs:
                return self.outputs[key]
        if default is not dataclasses.MISSING:
            return default
        raise MissingInputError(
            f"context key {key!r} not produced by any completed stage "
            f"(have: {sorted(self.outputs)})"
        )

    def put(self, **kw: Any) -> None:
        with self._lock:
            self.outputs.update(kw)

    # -- placement bindings (written by the scheduler) ------------------
    def bind_placement(self, name: str, placement: Placement) -> None:
        with self._lock:
            self._placements[name] = placement

    def placement(self, name: str) -> Optional[Placement]:
        """The backend the scheduler bound stage ``name`` to, if any.
        Names are as they appear in provenance — nested stages are
        prefixed (``prep/train``)."""
        with self._lock:
            return self._placements.get(name)

    def placements(self) -> Dict[str, Placement]:
        with self._lock:
            return dict(self._placements)

    def current_placement(self) -> Optional[Placement]:
        """The placement of the stage executing on *this* thread — what a
        stage body should read (collision-free even when nested
        subgraphs reuse stage names; the scheduler sets it around every
        ``run()`` call)."""
        return getattr(self._tls, "placement", None)


@dataclasses.dataclass
class StageResult:
    name: str
    ok: bool
    started_at: float
    duration_s: float
    output_keys: Tuple[str, ...] = ()
    error: Optional[str] = None
    cached: bool = False                 # outputs restored from StageCache
    resumed: bool = False                # outputs restored from RunManifest
    outputs_hash: Optional[str] = None   # structural hash of the outputs
    attempts: int = 1                    # 1 = first try succeeded
    placement: Optional[str] = None      # bound backend (render string)

    @property
    def skipped(self) -> bool:
        """True when the stage body never ran (cache or resume skip)."""
        return self.cached or self.resumed


# ===========================================================================
# The graph
# ===========================================================================
class StageGraph:
    """DAG of stages with deterministic, concurrency-aware scheduling."""

    def __init__(self, name: str = "workflow"):
        self.name = name
        self._stages: Dict[str, Stage] = {}
        self._deps: Dict[str, Tuple[str, ...]] = {}

    # -- construction ---------------------------------------------------
    def add(self, stage: Stage, depends_on: Sequence[str] = ()) -> Stage:
        if stage.name in self._stages:
            raise GraphError(f"stage {stage.name!r} already in graph {self.name!r}")
        self._stages[stage.name] = stage
        self._deps[stage.name] = tuple(dict.fromkeys(depends_on))
        return stage

    def add_fn(self, name: str, fn: Callable, depends_on: Sequence[str] = (),
               **kw) -> Stage:
        return self.add(FnStage(name, fn, **kw), depends_on=depends_on)

    @property
    def stages(self) -> Dict[str, Stage]:
        return dict(self._stages)

    def deps(self, name: str) -> Tuple[str, ...]:
        return self._deps[name]

    # -- validation -----------------------------------------------------
    def validate(self) -> None:
        for name, deps in self._deps.items():
            for d in deps:
                if d not in self._stages:
                    raise GraphError(
                        f"stage {name!r} depends on unknown stage {d!r}"
                    )
                if d == name:
                    raise CycleError(f"stage {name!r} depends on itself")
        producers: Dict[str, str] = {}
        for name, stage in self._stages.items():
            for key in stage.outputs:
                first = producers.setdefault(key, name)
                if first != name:
                    raise GraphError(
                        f"stages {first!r} and {name!r} both declare output "
                        f"key {key!r}; the second to finish would silently "
                        f"overwrite the first — rename one output (e.g. via "
                        f"state_key=) or drop the duplicate stage"
                    )
        self.topo_order()  # raises CycleError on cycles

    def _successors(self) -> Dict[str, List[str]]:
        """Successor adjacency (``dep -> [dependents...]``), dependents in
        insertion order — built once per traversal instead of rescanning
        every stage per completed node."""
        succ: Dict[str, List[str]] = {n: [] for n in self._stages}
        for m, deps in self._deps.items():
            for d in deps:
                if d in succ:
                    succ[d].append(m)
        return succ

    def topo_order(self) -> List[str]:
        """Kahn's algorithm; ready stages drain in insertion order, so the
        result is deterministic for a given construction sequence."""
        indeg = {n: 0 for n in self._stages}
        succ = self._successors()
        for n, deps in self._deps.items():
            for d in deps:
                if d in indeg:
                    indeg[n] += 1
        order: List[str] = []
        ready = deque(n for n in self._stages if indeg[n] == 0)
        while ready:
            n = ready.popleft()
            order.append(n)
            for m in succ[n]:
                indeg[m] -= 1
                if indeg[m] == 0:
                    ready.append(m)
        if len(order) != len(self._stages):
            stuck = sorted(set(self._stages) - set(order))
            raise CycleError(f"cycle among stages {stuck} in graph {self.name!r}")
        return order

    # -- composition ----------------------------------------------------
    def subgraph(self, targets: Sequence[str]) -> "StageGraph":
        """The induced graph of ``targets`` plus all their ancestors —
        what `cli run --stage X` executes."""
        for t in targets:
            if t not in self._stages:
                raise GraphError(
                    f"unknown stage {t!r}; graph has {sorted(self._stages)}"
                )
        keep = set()
        frontier = list(targets)
        while frontier:
            n = frontier.pop()
            if n in keep:
                continue
            keep.add(n)
            frontier.extend(self._deps[n])
        g = StageGraph(f"{self.name}[{','.join(targets)}]")
        for n in self._stages:  # preserve insertion order
            if n in keep:
                g.add(self._stages[n],
                      depends_on=tuple(d for d in self._deps[n] if d in keep))
        return g

    def as_stage(self, name: Optional[str] = None,
                 max_workers: int = 4,
                 retry: Optional[RestartPolicy] = None) -> Stage:
        """Wrap this whole graph as one stage of an outer graph
        (recursive subworkflow nesting).  ``retry`` becomes the inner
        graph's graph-level restart policy."""
        return _SubworkflowStage(name or self.name, self, max_workers, retry)

    # -- rendering ------------------------------------------------------
    def render(self, placements: Optional[Dict[str, str]] = None) -> str:
        """ASCII DAG in topological order (the CLI `graph` subcommand).

        ``placements`` maps stage names to resolved-backend strings
        (the CLI's ``graph --placements``); stages without an entry
        render as running on the local/default backend."""
        lines = [f"graph {self.name} ({len(self._stages)} stages)"]
        for n in self.topo_order():
            s = self._stages[n]
            deps = ", ".join(self._deps[n]) or "-"
            extra = ""
            if s.intent is not None:
                extra = f"  intent(goal={s.intent.goal})"
            io = ""
            if s.inputs or s.outputs:
                io = f"  [{','.join(s.inputs)}] -> [{','.join(s.outputs)}]"
            lines.append(f"  {n:<16s} <- {deps:<24s}{io}{extra}")
            if placements is not None:
                lines.append(f"  {'':<16s}    @ {placements.get(n, 'local')}")
        return "\n".join(lines)

    # -- execution ------------------------------------------------------
    def execute(self, ctx: StageContext, *, max_workers: int = 4,
                prefix: str = "",
                retry: Optional[RestartPolicy] = None,
                executor=None,
                ) -> Dict[str, StageResult]:
        """Run every stage, respecting edges, independent stages in
        parallel.

        ``retry`` is the graph-level restart policy: a stage failing with
        an exception the policy deems retryable is re-run (after backoff)
        up to ``max_restarts`` times, with ``stage_failed`` /
        ``stage_retry`` provenance events per attempt; a stage's own
        ``retry`` attribute overrides it.  Non-retryable stage exceptions
        propagate unchanged (after an ``ok=False`` stage_end event) so
        callers see e.g. BudgetExceeded exactly as the monolithic runner
        raised it.

        ``executor`` selects where stage *bodies* run (see
        :mod:`repro.core.executor`): None keeps them inline on the
        coordinator threads (historical behavior, identical to
        ``ThreadedExecutor``); a backend instance receives every
        ``dispatchable`` stage body via ``executor.submit(...)`` while
        the scheduling, retry, cache and provenance state machine stays
        on the coordinator.  The coordinator pool widens to the
        executor's ``schedule_width`` so a wide backend is never starved
        by a narrow coordinator."""
        self.validate()
        width = max(1, max_workers)
        if executor is not None:
            width = max(width, int(getattr(executor, "schedule_width", 0) or 0))
        indeg = {n: sum(1 for d in self._deps[n]) for n in self._stages}
        succ = self._successors()
        ready = [n for n in self.topo_order() if indeg[n] == 0]
        results: Dict[str, StageResult] = {}
        pending: Dict[Any, str] = {}

        def _launch(pool, name):
            stage = self._stages[name]
            placement = self._resolve_placement(name, ctx)
            if placement is not None:
                ctx.bind_placement(prefix + name, placement)
                if ctx.record is not None:
                    ctx.record.log_event("placement", {
                        **placement.as_doc(), "stage": prefix + name,
                    })
            if ctx.record is not None:
                ctx.record.log_event("stage_start", {"stage": prefix + name})
            input_hash = self._input_hash(name, ctx, results)
            fut = pool.submit(self._run_stage, stage, ctx, prefix,
                              input_hash, retry, placement, executor)
            pending[fut] = name

        failure: Optional[BaseException] = None
        with ThreadPoolExecutor(max_workers=width) as pool:
            for n in ready:
                _launch(pool, n)
            while pending:
                done, _ = wait(list(pending), return_when=FIRST_COMPLETED)
                for fut in done:
                    name = pending.pop(fut)
                    res, err = fut.result()
                    results[name] = res
                    if err is not None:
                        failure = failure or err
                        continue
                    for m in succ[name]:
                        indeg[m] -= 1
                        if indeg[m] == 0 and failure is None:
                            _launch(pool, m)
        if failure is not None:
            raise failure
        return results

    # -- placement ------------------------------------------------------
    def _resolve_placement(self, name: str,
                           ctx: StageContext) -> Optional[Placement]:
        """The backend stage ``name`` should run on, best-effort at launch
        time: the main workload's plan_choice (``placement_key ==
        "__main__"``), the stage's entry in an upstream PlanStage's
        ``stage_plans``, or a fresh planner pass over the stage's own
        ``intent``.  None when nothing is resolvable yet (e.g. a stage
        launched concurrently with the plan stage)."""
        stage = self._stages[name]
        choice = None
        if stage.placement_key == "__main__":
            choice = ctx.get("plan_choice", None)
        if choice is None:
            plans = ctx.get("stage_plans", None) or {}
            choice = plans.get(name)
        if choice is None and stage.intent is not None:
            from repro.core.planner import plan_stages

            try:
                choice = plan_stages({name: stage.intent}).get(name)
            except Exception:
                choice = None  # placement is advisory; never block launch
        if choice is None:
            return None
        return Placement.from_choice(name, choice)

    # -- content addressing ---------------------------------------------
    def _input_hash(self, name: str, ctx: StageContext,
                    results: Dict[str, StageResult]) -> Optional[str]:
        """The stage's content-addressed input key: stage signature +
        declared input values + upstream output hashes + the template
        fields and params the stage reads (see repro.core.stagecache).
        Used both as the cross-run cache key (cacheable stages) and the
        resume key (any stage, when a RunManifest is attached).  None
        when neither consumer is attached or an input is missing."""
        stage = self._stages[name]
        want_cache = stage.cacheable and ctx.cache is not None
        if not want_cache and ctx.resume is None:
            return None
        try:
            inputs = {k: _describe(ctx.get(k)) for k in stage.inputs}
        except MissingInputError:
            return None
        template = None
        if ctx.template is not None:
            fields = stage.cache_template_fields
            if fields is None:
                template = _describe(ctx.template)
            else:
                template = {f: _describe(getattr(ctx.template, f, None))
                            for f in fields}
        return stable_hash({
            "stage": stage.signature(),
            "inputs": inputs,
            "upstream": {d: results[d].outputs_hash
                         for d in sorted(self._deps[name]) if d in results},
            "template": template,
            "params": {k: _describe(ctx.params.get(k))
                       for k in stage.cache_params},
        })

    # -- the per-stage state machine ------------------------------------
    def _run_stage(self, stage: Stage, ctx: StageContext, prefix: str,
                   input_hash: Optional[str] = None,
                   graph_retry: Optional[RestartPolicy] = None,
                   placement: Optional[Placement] = None,
                   executor=None,
                   ) -> Tuple[StageResult, Optional[BaseException]]:
        t0 = time.perf_counter()
        started = time.time()
        full_name = prefix + stage.name
        place_str = placement.render() if placement is not None else None
        # expose the binding, the full provenance prefix and the run's
        # executor to the stage body thread-locally: unlike name-keyed
        # lookups this stays correct when nested subgraphs reuse stage
        # names, and lets a subworkflow stage extend the prefix (and
        # reuse the executor) at any nesting depth
        ctx._tls.placement = placement
        ctx._tls.prefix = prefix
        ctx._tls.executor = executor

        # 1) resume: this very run already completed the stage ----------
        if input_hash is not None and ctx.resume is not None \
                and stage.resume_safe(ctx):
            entry = ctx.resume.lookup(full_name, input_hash)
            if entry is not None:
                hit = ctx.resume.load_outputs(full_name, input_hash)
                if hit is not None and all(k in hit for k in stage.outputs):
                    ctx.put(**hit)
                    dt = time.perf_counter() - t0
                    ohash = entry.get("outputs_hash") or stable_hash(
                        _describe_outputs(hit))
                    if ctx.record is not None:
                        ctx.record.log_event("stage_cached", {
                            "stage": full_name, "input_hash": input_hash,
                            "outputs": sorted(hit), "resume": True,
                        })
                        ctx.record.log_event("stage_end", {
                            "stage": full_name, "ok": True,
                            "duration_s": dt, "cached": True, "resumed": True,
                            "outputs": sorted(hit), "outputs_hash": ohash,
                        })
                    return StageResult(stage.name, True, started, dt,
                                       output_keys=tuple(sorted(hit)),
                                       cached=True, resumed=True,
                                       outputs_hash=ohash,
                                       placement=place_str), None

        # 2) cross-run cache hit ----------------------------------------
        use_cache = (input_hash is not None and stage.cacheable
                     and ctx.cache is not None)
        if use_cache:
            hit = ctx.cache.get(input_hash)
            if hit is not None and all(k in hit for k in stage.outputs):
                ctx.put(**hit)
                dt = time.perf_counter() - t0
                ohash = stable_hash(_describe_outputs(hit))
                if ctx.record is not None:
                    ctx.record.log_event("stage_cached", {
                        "stage": full_name,
                        "input_hash": input_hash,
                        "outputs": sorted(hit),
                    })
                    ctx.record.log_event("stage_end", {
                        "stage": full_name, "ok": True,
                        "duration_s": dt, "cached": True,
                        "outputs": sorted(hit), "outputs_hash": ohash,
                    })
                if ctx.resume is not None:
                    # hash-only entry: a resume misses here, falls through
                    # to the cross-run cache and hits there — no need to
                    # pickle the payload a second time into the run dir
                    ctx.resume.record(full_name, input_hash, ohash, hit, dt,
                                      store_payload=False)
                return StageResult(stage.name, True, started, dt,
                                   output_keys=tuple(sorted(hit)),
                                   cached=True, outputs_hash=ohash,
                                   placement=place_str), None

        # 3) run, retrying under the restart policy ---------------------
        policy = stage.retry if stage.retry is not None else graph_retry
        failures = ctx.params.get("failures")
        attempt = 0
        while True:
            t_attempt = time.perf_counter()
            try:
                if failures is not None:
                    failures.check_stage(full_name)
                if executor is not None and stage.dispatchable:
                    out = executor.submit(
                        stage, ctx, name=full_name,
                        placement=placement, prefix=prefix).result()
                    out = out or {}
                else:
                    out = stage.run(ctx) or {}
                break
            except BaseException as e:  # noqa: BLE001 — re-raised below
                dt_attempt = time.perf_counter() - t_attempt
                retryable = policy is not None and policy.retryable(e)
                will_retry = retryable and attempt < policy.max_restarts
                if ctx.record is not None:
                    ctx.record.log_event("stage_failed", {
                        "stage": full_name, "attempt": attempt + 1,
                        "error": repr(e), "retryable": retryable,
                        "duration_s": dt_attempt,
                    })
                if not will_retry:
                    dt = time.perf_counter() - t0
                    res = StageResult(stage.name, False, started, dt,
                                      error=repr(e), attempts=attempt + 1,
                                      placement=place_str)
                    if ctx.record is not None:
                        ctx.record.log_event("stage_end", {
                            "stage": full_name, "ok": False,
                            "duration_s": dt, "error": repr(e),
                            "attempts": attempt + 1,
                        })
                    return res, e
                delay = policy.delay(attempt)
                if ctx.record is not None:
                    ctx.record.log_event("stage_retry", {
                        "stage": full_name, "attempt": attempt + 2,
                        "delay_s": delay,
                    })
                if delay > 0:
                    time.sleep(delay)
                attempt += 1

        # 4) success: validate declared outputs, publish, persist -------
        dt = time.perf_counter() - t0
        missing = [k for k in stage.outputs if k not in out]
        if missing:
            e = GraphError(
                f"stage {stage.name!r} declared outputs {missing} but did "
                f"not produce them (got {sorted(out)})"
            )
            if ctx.record is not None:
                ctx.record.log_event("stage_end", {
                    "stage": full_name, "ok": False,
                    "duration_s": dt, "error": repr(e),
                })
            return StageResult(stage.name, False, started, dt,
                               error=repr(e), attempts=attempt + 1,
                               placement=place_str), e
        ctx.put(**out)
        ohash = stable_hash(_describe_outputs(out))
        res = StageResult(stage.name, True, started, dt,
                          output_keys=tuple(sorted(out)),
                          outputs_hash=ohash, attempts=attempt + 1,
                          placement=place_str)
        if use_cache:
            ctx.cache.put(input_hash, full_name, out, dt)
        if input_hash is not None and ctx.resume is not None:
            # a cacheable stage's payload just went into the cross-run
            # cache — the manifest entry stays hash-only and resume
            # falls through to the cache, same as the hit path
            ctx.resume.record(full_name, input_hash, ohash, out, dt,
                              store_payload=stage.resume_payload
                              and not use_cache)
        if ctx.record is not None:
            end = {
                "stage": full_name, "ok": True, "duration_s": dt,
                "outputs": sorted(out),
                "outputs_hash": ohash,
            }
            if attempt:
                end["attempts"] = attempt + 1
            ctx.record.log_event("stage_end", end)
        return res, None


class _SubworkflowStage(Stage):
    """A nested StageGraph executing as a single stage of an outer graph.

    The inner graph shares the outer context (outputs blackboard, record,
    params); its stage events are prefixed ``<name>/``.
    """

    # the body is a nested scheduler — it must stay on the coordinator
    # thread (dispatching it into a bounded worker fleet could deadlock:
    # the subworkflow would hold a worker while waiting for workers)
    dispatchable = False

    def __init__(self, name: str, graph: StageGraph, max_workers: int = 4,
                 retry: Optional[RestartPolicy] = None):
        super().__init__(name)
        self.graph = graph
        self.max_workers = max_workers
        self.inner_retry = retry
        order = graph.topo_order()
        self.inputs = tuple(dict.fromkeys(
            k for n in order for k in graph.stages[n].inputs))
        self.outputs = tuple(dict.fromkeys(
            k for n in order for k in graph.stages[n].outputs))

    def spec_config(self) -> Dict[str, Any]:
        # the inner graph serializes as a nested "graph" block in the
        # spec entry (see repro.core.spec), not as opaque config
        return {"max_workers": self.max_workers}

    def run(self, ctx: StageContext) -> Dict[str, Any]:
        # extend the prefix we were launched under, so doubly-nested
        # stages register as 'outer/inner/stage' in provenance, failure
        # schedules, placements and the resume manifest
        outer = getattr(ctx._tls, "prefix", "")
        self.graph.execute(ctx, max_workers=self.max_workers,
                           prefix=outer + self.name + "/",
                           retry=self.inner_retry,
                           executor=getattr(ctx._tls, "executor", None))
        return {k: ctx.get(k) for k in self.outputs if k in ctx.outputs}
