"""Stage graph: the composable workflow DAG (paper §4.2 generalized).

A workflow is a directed acyclic graph of :class:`Stage` objects.  Each
stage declares the context keys it consumes (``inputs``) and produces
(``outputs``), an optional per-stage :class:`ResourceIntent` the planner
resolves independently (a cheap data-prep stage and an expensive train
stage can land on different slices), and a ``run(ctx)`` body.  The graph
executes stages in deterministic topological order, running independent
stages concurrently on a thread pool, and emits per-stage provenance
events (``stage_start`` / ``stage_end`` with timing and an outputs hash)
into the run's :class:`RunRecord`.

Graphs nest: ``inner.as_stage("prep")`` wraps a whole graph as a single
stage of an outer graph; nested stage events are name-prefixed
(``prep/tokenize``).

Authoring a custom stage::

    class MyStage(Stage):
        inputs = ("cfg",)
        outputs = ("thing",)
        def run(self, ctx):
            return {"thing": make_thing(ctx.get("cfg"))}

    g = StageGraph("demo")
    g.add(DataStage())
    g.add(MyStage("mine"), depends_on=("data",))
    g.execute(StageContext(template=t, record=rec))
"""
from __future__ import annotations

import dataclasses
import threading
import time
from concurrent.futures import FIRST_COMPLETED, ThreadPoolExecutor, wait
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.intent import ResourceIntent
from repro.core.provenance import RunRecord, stable_hash


class GraphError(ValueError):
    """Structural problem in a stage graph (duplicate, unknown dep, cycle)."""


def _describe(v):
    """A *structural* summary of a value for hashing: arrays describe by
    dtype/shape (their repr would truncate content and force a device
    sync on multi-GB states), primitives by value, dataclasses by full
    field content, everything else by type name.  Hashes built from this
    detect wiring changes — different keys, shapes, scalar or config
    values — not bitwise array equality."""
    if v is None or isinstance(v, (bool, int, float, str)):
        return v
    shape = getattr(v, "shape", None)
    dtype = getattr(v, "dtype", None)
    if shape is not None and dtype is not None:
        return f"{dtype}{tuple(shape)}"
    if dataclasses.is_dataclass(v) and not isinstance(v, type):
        return {"__dataclass__": type(v).__name__,
                **{f.name: _describe(getattr(v, f.name))
                   for f in dataclasses.fields(v)}}
    if isinstance(v, dict):
        return {str(k): _describe(x)
                for k, x in sorted(v.items(), key=lambda kv: str(kv[0]))}
    if isinstance(v, (list, tuple)):
        return [_describe(x) for x in v]
    return type(v).__name__


def _describe_outputs(out: Dict[str, Any]) -> Dict[str, Any]:
    return {k: _describe(out[k]) for k in sorted(out)}


class CycleError(GraphError):
    pass


class MissingInputError(KeyError):
    """A stage asked the context for a key no upstream stage produced."""


# ===========================================================================
# Stage & context
# ===========================================================================
class Stage:
    """One node of a workflow graph.

    Subclasses set ``name`` (unique within a graph), optionally declare
    ``inputs`` / ``outputs`` (context keys, used for validation and the
    CLI's DAG rendering), an ``intent`` (per-stage resource request the
    planner resolves via :func:`repro.core.planner.plan_stages`) and
    ``checks`` (names into the workflow CHECKS table), and implement
    ``run(ctx) -> dict`` returning the produced outputs.
    """

    name: str = "stage"
    inputs: Tuple[str, ...] = ()
    outputs: Tuple[str, ...] = ()
    intent: Optional[ResourceIntent] = None
    checks: Tuple[str, ...] = ()
    # -- cross-run caching (see repro.core.stagecache) ------------------
    # Only stages whose outputs are a pure function of the hashed inputs
    # should opt in; side-effectful stages (budget authorization, metric
    # logging, checkpoint writes) must stay uncacheable.
    cacheable: bool = False
    # ctx.params keys folded into the input hash (the knobs this stage
    # actually reads — keeps unrelated param changes from invalidating)
    cache_params: Tuple[str, ...] = ()
    # template fields folded into the input hash; None = whole template
    cache_template_fields: Optional[Tuple[str, ...]] = None
    # code-version salt: bump when the stage's implementation (or code it
    # calls into) changes output semantics, so stale entries can't hit
    cache_version: str = "1"

    def __init__(self, name: Optional[str] = None):
        if name is not None:
            self.name = name

    def run(self, ctx: "StageContext") -> Dict[str, Any]:
        raise NotImplementedError

    def signature(self) -> Dict[str, Any]:
        """JSON-able identity of this stage for the cache key: type,
        name, declared I/O, and its primitive constructor config."""
        cfg = {k: v for k, v in sorted(vars(self).items())
               if not k.startswith("_")
               and isinstance(v, (bool, int, float, str, tuple, list,
                                  dict, type(None)))}
        return {"type": type(self).__name__, "name": self.name,
                "version": self.cache_version,
                "inputs": list(self.inputs), "outputs": list(self.outputs),
                "config": _describe(cfg)}

    def __repr__(self):
        return f"<{type(self).__name__} {self.name!r}>"


class FnStage(Stage):
    """Wrap a plain callable ``fn(ctx) -> dict`` as a stage."""

    def __init__(self, name: str, fn: Callable[["StageContext"], Optional[Dict]],
                 inputs: Sequence[str] = (), outputs: Sequence[str] = (),
                 intent: Optional[ResourceIntent] = None):
        super().__init__(name)
        self.fn = fn
        self.inputs = tuple(inputs)
        self.outputs = tuple(outputs)
        self.intent = intent

    def run(self, ctx: "StageContext") -> Dict[str, Any]:
        return self.fn(ctx) or {}


@dataclasses.dataclass
class StageContext:
    """Shared state threaded through a graph execution.

    ``outputs`` is the blackboard stages read/write through ``get``/``put``
    (lock-guarded — stages may run concurrently); ``params`` carries
    run-scoped knobs (steps_override, smoke_batch, failures, intent);
    ``cache`` is an optional :class:`repro.core.stagecache.StageCache`
    the scheduler consults to skip cacheable stages across runs.
    """

    template: Any = None
    record: Optional[RunRecord] = None
    store: Any = None
    ledger: Any = None
    user: str = "anonymous"
    workspace: str = "default"
    cache: Any = None
    params: Dict[str, Any] = dataclasses.field(default_factory=dict)
    outputs: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        self._lock = threading.Lock()

    def get(self, key: str, default: Any = dataclasses.MISSING) -> Any:
        with self._lock:
            if key in self.outputs:
                return self.outputs[key]
        if default is not dataclasses.MISSING:
            return default
        raise MissingInputError(
            f"context key {key!r} not produced by any completed stage "
            f"(have: {sorted(self.outputs)})"
        )

    def put(self, **kw: Any) -> None:
        with self._lock:
            self.outputs.update(kw)


@dataclasses.dataclass
class StageResult:
    name: str
    ok: bool
    started_at: float
    duration_s: float
    output_keys: Tuple[str, ...] = ()
    error: Optional[str] = None
    cached: bool = False                 # outputs restored from StageCache
    outputs_hash: Optional[str] = None   # structural hash of the outputs


# ===========================================================================
# The graph
# ===========================================================================
class StageGraph:
    """DAG of stages with deterministic, concurrency-aware scheduling."""

    def __init__(self, name: str = "workflow"):
        self.name = name
        self._stages: Dict[str, Stage] = {}
        self._deps: Dict[str, Tuple[str, ...]] = {}

    # -- construction ---------------------------------------------------
    def add(self, stage: Stage, depends_on: Sequence[str] = ()) -> Stage:
        if stage.name in self._stages:
            raise GraphError(f"stage {stage.name!r} already in graph {self.name!r}")
        self._stages[stage.name] = stage
        self._deps[stage.name] = tuple(dict.fromkeys(depends_on))
        return stage

    def add_fn(self, name: str, fn: Callable, depends_on: Sequence[str] = (),
               **kw) -> Stage:
        return self.add(FnStage(name, fn, **kw), depends_on=depends_on)

    @property
    def stages(self) -> Dict[str, Stage]:
        return dict(self._stages)

    def deps(self, name: str) -> Tuple[str, ...]:
        return self._deps[name]

    # -- validation -----------------------------------------------------
    def validate(self) -> None:
        for name, deps in self._deps.items():
            for d in deps:
                if d not in self._stages:
                    raise GraphError(
                        f"stage {name!r} depends on unknown stage {d!r}"
                    )
                if d == name:
                    raise CycleError(f"stage {name!r} depends on itself")
        self.topo_order()  # raises CycleError on cycles

    def topo_order(self) -> List[str]:
        """Kahn's algorithm; ready stages drain in insertion order, so the
        result is deterministic for a given construction sequence."""
        indeg = {n: 0 for n in self._stages}
        for n, deps in self._deps.items():
            for d in deps:
                if d in indeg:
                    indeg[n] += 1
        order: List[str] = []
        ready = [n for n in self._stages if indeg[n] == 0]
        while ready:
            n = ready.pop(0)
            order.append(n)
            for m in self._stages:
                if n in self._deps[m]:
                    indeg[m] -= 1
                    if indeg[m] == 0:
                        ready.append(m)
        if len(order) != len(self._stages):
            stuck = sorted(set(self._stages) - set(order))
            raise CycleError(f"cycle among stages {stuck} in graph {self.name!r}")
        return order

    # -- composition ----------------------------------------------------
    def subgraph(self, targets: Sequence[str]) -> "StageGraph":
        """The induced graph of ``targets`` plus all their ancestors —
        what `cli run --stage X` executes."""
        for t in targets:
            if t not in self._stages:
                raise GraphError(
                    f"unknown stage {t!r}; graph has {sorted(self._stages)}"
                )
        keep = set()
        frontier = list(targets)
        while frontier:
            n = frontier.pop()
            if n in keep:
                continue
            keep.add(n)
            frontier.extend(self._deps[n])
        g = StageGraph(f"{self.name}[{','.join(targets)}]")
        for n in self._stages:  # preserve insertion order
            if n in keep:
                g.add(self._stages[n],
                      depends_on=tuple(d for d in self._deps[n] if d in keep))
        return g

    def as_stage(self, name: Optional[str] = None,
                 max_workers: int = 4) -> Stage:
        """Wrap this whole graph as one stage of an outer graph
        (recursive subworkflow nesting)."""
        return _SubworkflowStage(name or self.name, self, max_workers)

    # -- rendering ------------------------------------------------------
    def render(self) -> str:
        """ASCII DAG in topological order (the CLI `graph` subcommand)."""
        lines = [f"graph {self.name} ({len(self._stages)} stages)"]
        for n in self.topo_order():
            s = self._stages[n]
            deps = ", ".join(self._deps[n]) or "-"
            extra = ""
            if s.intent is not None:
                extra = f"  intent(goal={s.intent.goal})"
            io = ""
            if s.inputs or s.outputs:
                io = f"  [{','.join(s.inputs)}] -> [{','.join(s.outputs)}]"
            lines.append(f"  {n:<16s} <- {deps:<24s}{io}{extra}")
        return "\n".join(lines)

    # -- execution ------------------------------------------------------
    def execute(self, ctx: StageContext, *, max_workers: int = 4,
                prefix: str = "") -> Dict[str, StageResult]:
        """Run every stage, respecting edges, independent stages in
        parallel.  Stage exceptions propagate unchanged (after an
        ``ok=False`` stage_end event) so callers see e.g. BudgetExceeded
        exactly as the monolithic runner raised it."""
        self.validate()
        indeg = {n: sum(1 for d in self._deps[n]) for n in self._stages}
        ready = [n for n in self.topo_order() if indeg[n] == 0]
        results: Dict[str, StageResult] = {}
        pending: Dict[Any, str] = {}

        def _launch(pool, name):
            stage = self._stages[name]
            if ctx.record is not None:
                ctx.record.log_event("stage_start", {"stage": prefix + name})
            input_hash = self._input_hash(name, ctx, results)
            fut = pool.submit(self._run_stage, stage, ctx, prefix, input_hash)
            pending[fut] = name

        failure: Optional[BaseException] = None
        with ThreadPoolExecutor(max_workers=max(1, max_workers)) as pool:
            for n in ready:
                _launch(pool, n)
            while pending:
                done, _ = wait(list(pending), return_when=FIRST_COMPLETED)
                for fut in done:
                    name = pending.pop(fut)
                    res, err = fut.result()
                    results[name] = res
                    if err is not None:
                        failure = failure or err
                        continue
                    for m in self._stages:
                        if name in self._deps[m]:
                            indeg[m] -= 1
                            if indeg[m] == 0 and failure is None:
                                _launch(pool, m)
        if failure is not None:
            raise failure
        return results

    def _input_hash(self, name: str, ctx: StageContext,
                    results: Dict[str, StageResult]) -> Optional[str]:
        """The stage's content-addressed cache key: stage signature +
        declared input values + upstream output hashes + the template
        fields and params the stage reads (see repro.core.stagecache).
        None when the stage is uncacheable or no cache is attached."""
        stage = self._stages[name]
        if not stage.cacheable or ctx.cache is None:
            return None
        try:
            inputs = {k: _describe(ctx.get(k)) for k in stage.inputs}
        except MissingInputError:
            return None
        template = None
        if ctx.template is not None:
            fields = stage.cache_template_fields
            if fields is None:
                template = _describe(ctx.template)
            else:
                template = {f: _describe(getattr(ctx.template, f, None))
                            for f in fields}
        return stable_hash({
            "stage": stage.signature(),
            "inputs": inputs,
            "upstream": {d: results[d].outputs_hash
                         for d in sorted(self._deps[name]) if d in results},
            "template": template,
            "params": {k: _describe(ctx.params.get(k))
                       for k in stage.cache_params},
        })

    def _run_stage(self, stage: Stage, ctx: StageContext, prefix: str,
                   input_hash: Optional[str] = None,
                   ) -> Tuple[StageResult, Optional[BaseException]]:
        t0 = time.perf_counter()
        started = time.time()
        if input_hash is not None and ctx.cache is not None:
            hit = ctx.cache.get(input_hash)
            if hit is not None and all(k in hit for k in stage.outputs):
                ctx.put(**hit)
                dt = time.perf_counter() - t0
                ohash = stable_hash(_describe_outputs(hit))
                if ctx.record is not None:
                    ctx.record.log_event("stage_cached", {
                        "stage": prefix + stage.name,
                        "input_hash": input_hash,
                        "outputs": sorted(hit),
                    })
                    ctx.record.log_event("stage_end", {
                        "stage": prefix + stage.name, "ok": True,
                        "duration_s": dt, "cached": True,
                        "outputs": sorted(hit), "outputs_hash": ohash,
                    })
                return StageResult(stage.name, True, started, dt,
                                   output_keys=tuple(sorted(hit)),
                                   cached=True, outputs_hash=ohash), None
        try:
            out = stage.run(ctx) or {}
        except BaseException as e:  # noqa: BLE001 — re-raised by execute()
            dt = time.perf_counter() - t0
            res = StageResult(stage.name, False, started, dt, error=repr(e))
            if ctx.record is not None:
                ctx.record.log_event("stage_end", {
                    "stage": prefix + stage.name, "ok": False,
                    "duration_s": dt, "error": repr(e),
                })
            return res, e
        dt = time.perf_counter() - t0
        missing = [k for k in stage.outputs if k not in out]
        if missing:
            e = GraphError(
                f"stage {stage.name!r} declared outputs {missing} but did "
                f"not produce them (got {sorted(out)})"
            )
            if ctx.record is not None:
                ctx.record.log_event("stage_end", {
                    "stage": prefix + stage.name, "ok": False,
                    "duration_s": dt, "error": repr(e),
                })
            return StageResult(stage.name, False, started, dt,
                               error=repr(e)), e
        ctx.put(**out)
        ohash = stable_hash(_describe_outputs(out))
        res = StageResult(stage.name, True, started, dt,
                          output_keys=tuple(sorted(out)),
                          outputs_hash=ohash)
        if input_hash is not None and ctx.cache is not None:
            ctx.cache.put(input_hash, prefix + stage.name, out, dt)
        if ctx.record is not None:
            ctx.record.log_event("stage_end", {
                "stage": prefix + stage.name, "ok": True, "duration_s": dt,
                "outputs": sorted(out),
                "outputs_hash": ohash,
            })
        return res, None


class _SubworkflowStage(Stage):
    """A nested StageGraph executing as a single stage of an outer graph.

    The inner graph shares the outer context (outputs blackboard, record,
    params); its stage events are prefixed ``<name>/``.
    """

    def __init__(self, name: str, graph: StageGraph, max_workers: int = 4):
        super().__init__(name)
        self.graph = graph
        self.max_workers = max_workers
        order = graph.topo_order()
        self.inputs = tuple(dict.fromkeys(
            k for n in order for k in graph.stages[n].inputs))
        self.outputs = tuple(dict.fromkeys(
            k for n in order for k in graph.stages[n].outputs))

    def run(self, ctx: StageContext) -> Dict[str, Any]:
        self.graph.execute(ctx, max_workers=self.max_workers,
                           prefix=self.name + "/")
        return {k: ctx.get(k) for k in self.outputs if k in ctx.outputs}
