"""Pluggable stage executors — the execution substrate behind the graph.

Adviser's pitch is that the *platform* manages parallel or distributed
execution; the user only declares the workflow.  `StageGraph.execute`
keeps its deterministic topological scheduler (the coordinator), but the
*stage body* — ``stage.run(ctx)`` — is dispatched through an
:class:`Executor`, selectable per run:

* :class:`ThreadedExecutor` (``--executor threads``, the default) — the
  body runs inline on the coordinator thread that claimed the stage.
  This is byte-for-byte today's behavior: concurrency comes from the
  graph's coordinator pool, stages share one interpreter.
* :class:`LocalPoolExecutor` (``--executor processes``) — the body of a
  ``process_safe`` stage is marshalled (pickle, the same machinery
  `StageCache`/`RunManifest` persist outputs with) into a
  ``ProcessPoolExecutor`` child, escaping the GIL for CPU-bound
  data/eval stages.  Stages that are not process-safe, or whose inputs
  or outputs refuse to pickle, fall back to inline execution — the
  executor degrades, it never wedges a run.  A child killed mid-stage
  surfaces as :class:`~repro.ft.failures.WorkerLost` (retryable under
  the default `RestartPolicy`) and the pool is rebuilt lazily.
* :class:`WorkerQueueExecutor` (``--executor workers``) — a local
  multi-worker job queue in the scitq/COSMOS job-manager mould: worker
  loops are *recruited* per stage up to the stage's
  ``ResourceIntent.min_chips`` (bounded by ``max_workers``), each claim
  takes a heartbeat-renewed **lease**, a stale-lease reaper requeues
  stages whose worker went silent (emitting ``worker_lost``
  provenance), and the bounded submission queue applies backpressure to
  the coordinator.  Chaos hooks (:meth:`WorkerQueueExecutor.kill_worker`,
  :meth:`WorkerQueueExecutor.drop_heartbeats`) make fault drills
  deterministic — no wall-clock races.

Executors are deliberately *synchronous-friendly*: ``submit`` may run
the body before returning and hand back an already-resolved
:class:`~concurrent.futures.Future`.  Parallelism across stages comes
from the coordinator pool calling ``submit`` from many threads, so a
backend only needs to decide *where* a body runs, never *when*.
"""
from __future__ import annotations

import collections
import itertools
import os
import pickle
import queue
import threading
import time
from concurrent.futures import Future, ProcessPoolExecutor
from concurrent.futures.process import BrokenProcessPool
from typing import Any, Dict, List, Optional, Tuple

from repro.ft.failures import WorkerLost

EXECUTOR_KINDS = ("threads", "processes", "workers")


class UnpicklableOutputs(RuntimeError):
    """Raised *inside* a pool child when a stage's outputs refuse to
    pickle; the parent falls back to re-running the body inline.
    Module-level so the exception itself crosses the process boundary.
    """


def _inline_run(stage, ctx) -> Dict[str, Any]:
    """The one true inline body: exactly what graph.py historically ran."""
    return stage.run(ctx) or {}


def _log_event(ctx, kind: str, **payload) -> None:
    record = getattr(ctx, "record", None)
    if record is not None:
        record.log_event(kind, dict(payload))


class Executor:
    """Where stage bodies run.

    The protocol is three methods — ``submit(stage, ctx, ...) -> Future``,
    ``capacity()`` and ``shutdown()``.  ``schedule_width`` advertises how
    many bodies the backend can usefully hold in flight; the graph sizes
    its coordinator pool to at least this so a wide backend is never
    starved by a narrow coordinator.
    """

    kind: str = "base"
    schedule_width: int = 1

    def submit(self, stage, ctx, *, name: Optional[str] = None,
               placement=None, prefix: str = "") -> "Future":
        raise NotImplementedError

    def capacity(self) -> int:
        return self.schedule_width

    def shutdown(self, wait: bool = True) -> None:  # pragma: no cover - trivial
        pass

    def stats(self) -> Dict[str, Any]:
        return {"kind": self.kind, "capacity": self.capacity()}

    # context-manager sugar so examples/benches can ``with make_executor(...)``
    def __enter__(self) -> "Executor":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()


class ThreadedExecutor(Executor):
    """Today's behavior, made explicit: the body runs inline on the
    coordinator thread that claimed the stage.  ``workers`` only sets the
    advertised ``schedule_width`` (how wide the graph's coordinator pool
    opens up); there is no second thread pool to hop through.
    """

    kind = "threads"

    def __init__(self, workers: int = 4):
        self.schedule_width = max(1, int(workers))
        self._submitted = 0

    def submit(self, stage, ctx, *, name=None, placement=None, prefix=""):
        fut: Future = Future()
        fut.set_running_or_notify_cancel()
        self._submitted += 1
        try:
            fut.set_result(_inline_run(stage, ctx))
        except BaseException as exc:  # noqa: BLE001 - future carries it
            fut.set_exception(exc)
        return fut

    def stats(self):
        return {"kind": self.kind, "capacity": self.capacity(),
                "submitted": self._submitted}


# --------------------------------------------------------------------------
# Process pool
# --------------------------------------------------------------------------

def _child_run(payload: bytes) -> Tuple[int, bytes]:
    """Pool-child entrypoint: rebuild a bare `StageContext` and run the
    stage body.  Returns ``(pid, pickled outputs)`` so the parent can
    attribute the work in provenance.
    """
    from repro.core.graph import StageContext

    stage, outputs, params, template = pickle.loads(payload)
    ctx = StageContext(template=template, record=None, params=params,
                       outputs=outputs)
    out = _inline_run(stage, ctx)
    try:
        blob = pickle.dumps(out)
    except Exception as exc:
        raise UnpicklableOutputs(
            f"stage {stage.name!r} outputs do not pickle: {exc}") from None
    return os.getpid(), blob


def _pickle_filter(mapping: Dict[str, Any]) -> Dict[str, Any]:
    """Drop entries that refuse to pickle (locks, schedules, live jax
    state).  A process-safe stage only depends on its declared inputs,
    which are persistable by the cache contract."""
    keep = {}
    for key, value in mapping.items():
        try:
            pickle.dumps(value)
        except Exception:
            continue
        keep[key] = value
    return keep


class LocalPoolExecutor(Executor):
    """`ProcessPoolExecutor`-backed stage bodies — escapes the GIL.

    Only stages marked ``process_safe`` (pure functions of their
    picklable inputs: `DataStage`, `EvalStage`, user stages that opt in)
    are dispatched to children; everything else runs inline on the
    coordinator thread.  Marshalling ships ``(stage, picklable ctx
    outputs, picklable params, template)`` — the same pickle surface the
    stage cache persists — and unpicklable *inputs or outputs* fall back
    inline rather than failing the run.

    A pool child dying mid-stage (OOM-kill, SIGKILL chaos drills)
    surfaces as :class:`WorkerLost`, which the default `RestartPolicy`
    retries; the broken pool is discarded and rebuilt on the next
    submit.  Note a pool break takes *all* in-flight bodies with it —
    per-item blast-radius isolation is the worker queue's job.
    """

    kind = "processes"

    def __init__(self, workers: Optional[int] = None, mp_context: Optional[str] = None,
                 warm: bool = True):
        self.workers = max(1, int(workers or min(4, os.cpu_count() or 1)))
        self.schedule_width = self.workers
        # fork avoids re-importing __main__ (and works for script-less
        # parents); children only run pure-Python stage bodies, so the
        # usual fork-with-threads hazards (jax, BLAS pools) stay out of
        # the child's execution path.
        self._mp_method = mp_context or ("fork" if hasattr(os, "fork") else "spawn")
        self._lock = threading.Lock()
        self._pool: Optional[ProcessPoolExecutor] = None
        self._rebuilds = 0
        self._inline_fallbacks = 0
        self._dispatched = 0
        if warm:
            self._ensure_pool()

    # -- pool lifecycle ----------------------------------------------------
    def _ensure_pool(self) -> ProcessPoolExecutor:
        with self._lock:
            if self._pool is None:
                import multiprocessing as mp

                self._pool = ProcessPoolExecutor(
                    max_workers=self.workers,
                    mp_context=mp.get_context(self._mp_method))
                # Force worker spawn now, from the calling thread, so
                # forks don't happen at an arbitrary later moment.
                self._pool.submit(os.getpid).result()
            return self._pool

    def _discard_pool(self) -> None:
        with self._lock:
            pool, self._pool = self._pool, None
            self._rebuilds += 1
        if pool is not None:
            pool.shutdown(wait=False)

    def worker_pids(self) -> List[int]:
        """Live child pids — the chaos hook SIGKILL drills target."""
        pool = self._ensure_pool()
        with self._lock:
            procs = getattr(pool, "_processes", None) or {}
            return [pid for pid, proc in dict(procs).items() if proc.is_alive()]

    # -- dispatch ----------------------------------------------------------
    def submit(self, stage, ctx, *, name=None, placement=None, prefix=""):
        fut: Future = Future()
        fut.set_running_or_notify_cancel()
        try:
            fut.set_result(self._run_body(stage, ctx, name or stage.name))
        except BaseException as exc:  # noqa: BLE001 - future carries it
            fut.set_exception(exc)
        return fut

    def _run_body(self, stage, ctx, name: str) -> Dict[str, Any]:
        if not (getattr(stage, "dispatchable", True)
                and getattr(stage, "process_safe", False)):
            return self._inline(stage, ctx, name, reason="not process-safe")
        payload = self._marshal(stage, ctx)
        if payload is None:
            return self._inline(stage, ctx, name, reason="unpicklable stage")
        pool = self._ensure_pool()
        try:
            pid, blob = pool.submit(_child_run, payload).result()
        except UnpicklableOutputs:
            return self._inline(stage, ctx, name, reason="unpicklable outputs")
        except BrokenProcessPool as exc:
            self._discard_pool()
            raise WorkerLost(
                f"process-pool worker died while running stage {name!r}") from exc
        self._dispatched += 1
        _log_event(ctx, "stage_worker", stage=name, worker=f"pid:{pid}",
                   backend=self.kind)
        out = pickle.loads(blob)
        return out

    def _inline(self, stage, ctx, name: str, *, reason: str) -> Dict[str, Any]:
        self._inline_fallbacks += 1
        _log_event(ctx, "stage_worker", stage=name, worker="inline",
                   backend=self.kind, fallback=reason)
        return _inline_run(stage, ctx)

    def _marshal(self, stage, ctx) -> Optional[bytes]:
        with ctx._lock:
            outputs = dict(ctx.outputs)
        params = dict(getattr(ctx, "params", {}) or {})
        template = getattr(ctx, "template", None)
        try:
            return pickle.dumps((stage, outputs, params, template))
        except Exception:
            pass
        # Second pass: drop the unpicklable entries (FailureSchedule
        # carries a lock, live model state may not pickle) and retry.
        outputs = _pickle_filter(outputs)
        params = _pickle_filter(params)
        for candidate in ((stage, outputs, params, template),
                          (stage, outputs, params, None)):
            try:
                return pickle.dumps(candidate)
            except Exception:
                continue
        return None

    def capacity(self) -> int:
        return self.workers

    def shutdown(self, wait: bool = True) -> None:
        with self._lock:
            pool, self._pool = self._pool, None
        if pool is not None:
            pool.shutdown(wait=wait)

    def stats(self):
        return {"kind": self.kind, "capacity": self.workers,
                "dispatched": self._dispatched,
                "inline_fallbacks": self._inline_fallbacks,
                "pool_rebuilds": self._rebuilds}


# --------------------------------------------------------------------------
# Worker queue
# --------------------------------------------------------------------------

class _Worker:
    __slots__ = ("id", "thread", "alive", "killed", "beats_dropped",
                 "current", "last_beat", "claim_epoch")

    def __init__(self, wid: str):
        self.id = wid
        self.thread: Optional[threading.Thread] = None
        self.alive = True
        self.killed = False          # chaos: stop executing + stop beating
        self.beats_dropped = False   # chaos: keep executing, stop beating
        self.current: Optional["_QueueItem"] = None
        self.last_beat = time.monotonic()
        self.claim_epoch = -1


class _QueueItem:
    __slots__ = ("seq", "stage", "ctx", "name", "placement", "prefix",
                 "future", "attempts", "epoch")

    def __init__(self, seq: int, stage, ctx, name: str, placement, prefix: str):
        self.seq = seq
        self.stage = stage
        self.ctx = ctx
        self.name = name
        self.placement = placement
        self.prefix = prefix
        self.future: Future = Future()
        self.future.set_running_or_notify_cancel()
        self.attempts = 0
        # Bumped by the reaper on every revocation; a worker's completion
        # only counts if the epoch it claimed under is still current —
        # zombie results from reaped workers are discarded, never
        # double-resolved.
        self.epoch = 0


class WorkerQueueExecutor(Executor):
    """A local multi-worker job queue with leases, heartbeats and a
    stale-lease reaper — the single-host rehearsal of a distributed
    worker fleet (scitq recruits workers per step the same way).

    * **Recruitment** is elastic: the fleet starts at ``workers`` loops
      and grows toward a stage's ``ResourceIntent.min_chips`` (capped at
      ``max_workers``) when a bigger stage arrives; idle surplus workers
      retire back down to the floor.
    * **Leases**: claiming a stage takes a lease (``stage_lease``
      provenance).  A maintenance thread renews heartbeats for healthy
      workers; a worker whose heartbeat goes stale for ``lease_s`` has
      its lease revoked by the reaper — the stage is requeued
      (``worker_lost`` provenance, up to ``max_requeues`` times, after
      which :class:`WorkerLost` surfaces to the retry policy) and a
      replacement worker is recruited.
    * **Backpressure**: the submission queue is bounded
      (``queue_size``); `submit` blocks the coordinator thread when the
      fleet is saturated.  Requeued work bypasses the bound (the reaper
      must never deadlock against a full queue).

    Chaos hooks: :meth:`kill_worker` (worker stops executing *and*
    beating — a crashed process), :meth:`drop_heartbeats` (worker keeps
    executing but goes silent — a network partition; its eventual result
    is discarded as a zombie).
    """

    kind = "workers"

    def __init__(self, workers: int = 2, max_workers: Optional[int] = None,
                 queue_size: int = 64, lease_s: float = 1.0,
                 poll_s: float = 0.02, max_requeues: int = 2):
        self.workers = max(1, int(workers))
        self.max_workers = max(self.workers, int(max_workers or self.workers * 4))
        self.schedule_width = self.max_workers
        self.lease_s = float(lease_s)
        self.poll_s = float(poll_s)
        self.max_requeues = int(max_requeues)
        self._queue: "queue.Queue[_QueueItem]" = queue.Queue(maxsize=max(1, queue_size))
        self._requeued: "collections.deque[_QueueItem]" = collections.deque()
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        self._workers: List[_Worker] = []
        self._running = True
        self._seq = itertools.count()
        self._wid = itertools.count(1)
        self._inflight = 0
        self._completed = 0
        self._requeues = 0
        self._discarded_zombies = 0
        self._recruited_total = 0
        for _ in range(self.workers):
            self._spawn_worker_locked_free()
        self._maint = threading.Thread(target=self._maintenance_loop,
                                       name="workerqueue-maint", daemon=True)
        self._maint.start()

    # -- fleet management --------------------------------------------------
    def _spawn_worker_locked_free(self) -> _Worker:
        worker = _Worker(f"w{next(self._wid)}")
        worker.thread = threading.Thread(target=self._worker_loop,
                                         args=(worker,),
                                         name=f"workerqueue-{worker.id}",
                                         daemon=True)
        with self._lock:
            self._workers.append(worker)
            self._recruited_total += 1
        worker.thread.start()
        return worker

    def _alive_locked(self) -> List[_Worker]:
        return [w for w in self._workers if w.alive and not w.killed]

    def _desired_for(self, stage) -> int:
        intent = getattr(stage, "intent", None)
        want = self.workers
        if intent is not None and getattr(intent, "min_chips", None):
            want = max(want, int(intent.min_chips))
        return min(self.max_workers, want)

    def _recruit_for(self, stage, ctx, name: str) -> None:
        want = self._desired_for(stage)
        spawned = []
        while True:
            with self._lock:
                if not self._running or len(self._alive_locked()) >= want:
                    break
            spawned.append(self._spawn_worker_locked_free().id)
        if spawned:
            _log_event(ctx, "worker_recruited", stage=name, workers=spawned,
                       fleet=self.capacity())

    # -- submission --------------------------------------------------------
    def submit(self, stage, ctx, *, name=None, placement=None, prefix=""):
        with self._lock:
            if not self._running:
                raise RuntimeError("WorkerQueueExecutor is shut down")
            self._inflight += 1
        item = _QueueItem(next(self._seq), stage, ctx, name or stage.name,
                          placement, prefix)
        self._recruit_for(stage, ctx, item.name)
        self._queue.put(item)  # bounded: blocks the coordinator = backpressure
        return item.future

    # -- worker loop -------------------------------------------------------
    def _claim_locked(self) -> Optional[_QueueItem]:
        if self._requeued:
            return self._requeued.popleft()
        return None

    def _worker_loop(self, worker: _Worker) -> None:
        while True:
            with self._lock:
                if not self._running or worker.killed:
                    worker.alive = False
                    self._cond.notify_all()
                    return
                item = self._claim_locked()
            if item is None:
                try:
                    item = self._queue.get(timeout=self.poll_s)
                except queue.Empty:
                    # surplus worker with nothing to do retires back to
                    # the fleet floor
                    with self._lock:
                        surplus = (len(self._alive_locked()) > self.workers
                                   and not self._requeued
                                   and self._queue.empty())
                        if surplus:
                            worker.alive = False
                            self._cond.notify_all()
                            return
                    continue
            with self._lock:
                if not self._running or worker.killed:
                    # hand the claim back rather than dropping it
                    self._requeued.appendleft(item)
                    worker.alive = False
                    self._cond.notify_all()
                    return
                item.attempts += 1
                worker.current = item
                worker.last_beat = time.monotonic()
                worker.claim_epoch = item.epoch
                attempt = item.attempts
            _log_event(item.ctx, "stage_lease", stage=item.name,
                       worker=worker.id, attempt=attempt,
                       lease_s=self.lease_s)
            out = err = None
            try:
                # the body runs on *this* thread, not the coordinator's:
                # re-establish the thread-local placement/prefix the
                # coordinator bound (ctx.current_placement contract)
                tls = getattr(item.ctx, "_tls", None)
                if tls is not None:
                    tls.placement = item.placement
                    tls.prefix = item.prefix
                out = _inline_run(item.stage, item.ctx)
            except BaseException as exc:  # noqa: BLE001 - future carries it
                err = exc
            with self._lock:
                stale = item.epoch != worker.claim_epoch
                if worker.current is item:
                    worker.current = None
                if stale:
                    # the reaper revoked this lease mid-flight; the item
                    # was requeued (or failed over) — this result is a
                    # zombie and must be discarded, not double-resolved.
                    self._discarded_zombies += 1
                    continue
            if err is not None:
                self._resolve(item, error=err)
            else:
                _log_event(item.ctx, "stage_worker", stage=item.name,
                           worker=worker.id, backend=self.kind,
                           attempt=attempt)
                self._resolve(item, result=out)

    def _resolve(self, item: _QueueItem, result=None, error=None) -> None:
        if error is not None:
            item.future.set_exception(error)
        else:
            item.future.set_result(result)
        with self._lock:
            self._inflight -= 1
            self._completed += 1
            self._cond.notify_all()

    # -- maintenance: heartbeats + stale-lease reaper ----------------------
    def _maintenance_loop(self) -> None:
        while True:
            time.sleep(self.poll_s)
            lost: List[Tuple[_Worker, _QueueItem, bool]] = []
            with self._lock:
                if not self._running:
                    return
                now = time.monotonic()
                for worker in self._workers:
                    if not worker.alive:
                        continue
                    if not (worker.killed or worker.beats_dropped):
                        worker.last_beat = now  # healthy worker heartbeat
                        continue
                    item = worker.current
                    if item is None:
                        continue
                    if now - worker.last_beat < self.lease_s:
                        continue
                    # lease expired: revoke, requeue (or fail over)
                    item.epoch += 1
                    worker.current = None
                    worker.killed = True  # a reaped worker never rejoins
                    requeue = item.attempts <= self.max_requeues
                    if requeue:
                        self._requeues += 1
                        self._requeued.append(item)
                    lost.append((worker, item, requeue))
            for worker, item, requeue in lost:
                _log_event(item.ctx, "worker_lost", stage=item.name,
                           worker=worker.id, attempt=item.attempts,
                           requeued=requeue)
                if requeue:
                    # keep the fleet at strength for the retry
                    self._recruit_for(item.stage, item.ctx, item.name)
                else:
                    self._resolve(item, error=WorkerLost(
                        f"stage {item.name!r} lost its worker "
                        f"{item.attempts} time(s); requeue budget "
                        f"({self.max_requeues}) exhausted"))

    # -- chaos hooks -------------------------------------------------------
    def kill_worker(self, worker_id: Optional[str] = None) -> Optional[str]:
        """Simulate a worker crash: it stops heartbeating *and* executing
        (its in-flight result, if any, is discarded).  Returns the id of
        the killed worker, preferring one that is mid-stage."""
        with self._lock:
            candidates = [w for w in self._alive_locked()]
            if worker_id is not None:
                candidates = [w for w in candidates if w.id == worker_id]
            busy = [w for w in candidates if w.current is not None]
            target = (busy or candidates or [None])[0]
            if target is None:
                return None
            target.killed = True
            return target.id

    def drop_heartbeats(self, worker_id: Optional[str] = None) -> Optional[str]:
        """Simulate a network partition: the worker keeps executing but
        goes silent, so the reaper revokes its lease and its eventual
        result is discarded as a zombie."""
        with self._lock:
            candidates = [w for w in self._alive_locked()]
            if worker_id is not None:
                candidates = [w for w in candidates if w.id == worker_id]
            busy = [w for w in candidates if w.current is not None]
            target = (busy or candidates or [None])[0]
            if target is None:
                return None
            target.beats_dropped = True
            return target.id

    # -- introspection / lifecycle ----------------------------------------
    def worker_ids(self) -> List[str]:
        with self._lock:
            return [w.id for w in self._alive_locked()]

    def capacity(self) -> int:
        with self._lock:
            return len(self._alive_locked())

    def drain(self, timeout: Optional[float] = None) -> bool:
        """Block until every submitted stage has resolved."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._cond:
            while self._inflight > 0:
                remaining = None
                if deadline is not None:
                    remaining = deadline - time.monotonic()
                    if remaining <= 0:
                        return False
                self._cond.wait(remaining if remaining is not None else 0.1)
        return True

    def shutdown(self, wait: bool = True) -> None:
        if wait:
            self.drain()
        with self._lock:
            if not self._running:
                return
            self._running = False
            workers = list(self._workers)
            self._cond.notify_all()
        for worker in workers:
            if worker.thread is not None and wait:
                worker.thread.join(timeout=5.0)
        if wait and self._maint.is_alive():
            self._maint.join(timeout=5.0)
        # anything still unresolved (zombies revoked past their budget at
        # shutdown, claims handed back with no fleet left) fails loudly
        pending: List[_QueueItem] = []
        with self._lock:
            pending.extend(self._requeued)
            self._requeued.clear()
        while True:
            try:
                pending.append(self._queue.get_nowait())
            except queue.Empty:
                break
        for item in pending:
            if not item.future.done():
                self._resolve(item, error=RuntimeError(
                    f"executor shut down with stage {item.name!r} pending"))

    def stats(self):
        with self._lock:
            return {"kind": self.kind,
                    "capacity": len(self._alive_locked()),
                    "fleet_floor": self.workers,
                    "fleet_ceiling": self.max_workers,
                    "inflight": self._inflight,
                    "completed": self._completed,
                    "requeues": self._requeues,
                    "discarded_zombies": self._discarded_zombies,
                    "recruited_total": self._recruited_total}


def make_executor(kind: str, workers: Optional[int] = None, **kw) -> Executor:
    """CLI-facing factory: ``threads`` / ``processes`` / ``workers``."""
    kind = (kind or "threads").lower()
    if kind == "threads":
        return ThreadedExecutor(workers=workers or 4)
    if kind == "processes":
        return LocalPoolExecutor(workers=workers, **kw)
    if kind == "workers":
        return WorkerQueueExecutor(workers=workers or 2, **kw)
    raise ValueError(
        f"unknown executor kind {kind!r}; expected one of {EXECUTOR_KINDS}")
