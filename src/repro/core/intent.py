"""Resource intent: what the user *means*, not which hardware to use.

The paper's CLI shows the idea: ``adviser run "python train.py" --gpu 1
--ram 32`` — capabilities and constraints, never instance types.  Our
equivalent captures the knobs a scientist actually has: the workload
(arch × shape), a goal, and optional constraints (budget, deadline,
chip-count bounds).  Explicit overrides (``slice_name``, ``mesh_shape``)
remain available for experts — the paper's third CLI example.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ResourceIntent:
    arch: str
    shape: str
    goal: str = "production"  # production | quick_test | exploration
    # constraints (all optional — the planner fills the gaps)
    budget_usd_per_hour: Optional[float] = None
    max_step_seconds: Optional[float] = None
    min_chips: Optional[int] = None
    max_chips: Optional[int] = None
    chip_generation: Optional[str] = None  # v4 | v5e | v5p
    allow_multi_pod: bool = True
    # expert overrides (bypass parts of the search)
    slice_name: Optional[str] = None
    mesh_shape: Optional[Tuple[int, ...]] = None

    def validate(self) -> None:
        if self.goal not in ("production", "quick_test", "exploration"):
            raise ValueError(
                f"unknown goal {self.goal!r}; expected production, "
                f"quick_test or exploration"
            )
        if self.min_chips and self.max_chips and self.min_chips > self.max_chips:
            raise ValueError(
                f"min_chips ({self.min_chips}) exceeds max_chips "
                f"({self.max_chips})"
            )

    def with_goal(self, goal: str) -> "ResourceIntent":
        """A copy re-aimed at another goal — how a workflow gives its
        cheap stages (data prep) a different target than its train stage."""
        out = dataclasses.replace(self, goal=goal)
        out.validate()
        return out
