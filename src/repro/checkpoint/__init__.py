"""Checkpointing: async (snapshot on caller thread, serialize on a
background thread), atomic (temp dir + fsync + rename — a worker killed
mid-save can never corrupt the newest committed step), rotating, and
self-describing (a manifest records pytree structure/shapes/dtypes so
elastic restarts can reshard onto a different mesh).  The restore path
optionally places leaves directly onto target shardings — the hook the
resume and elastic-restart flows use."""
from repro.checkpoint.checkpointer import Checkpointer

__all__ = ["Checkpointer"]
