"""Checkpointing: async save, atomic commit, rotation, exact restore.

No orbax in this container — this is a from-scratch implementation with
the properties a 1000-node deployment needs:

  * **async**: the host copy of the state is snapshotted (device→host) on
    the caller thread, serialization + fsync happen on a background
    thread, so the train loop is blocked only for the device sync;
  * **atomic**: writes go to ``step_XXXX.tmp`` and are renamed only after
    fsync — a worker killed mid-save can never corrupt the latest
    checkpoint (restore picks the newest *committed* step);
  * **rotation**: keep the last N checkpoints;
  * **self-describing**: a manifest records the pytree structure, shapes,
    dtypes and the run's provenance id, so elastic restarts can reshard.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

Pytree = Any
_SEP = "/"

# numpy cannot serialize ml_dtypes (bfloat16 etc.) through savez; encode
# them as same-width unsigned views and record the true dtype.
_VIEW_ENCODE = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
                "float8_e5m2": np.uint8}


def _encode(v: np.ndarray) -> Tuple[np.ndarray, str]:
    name = v.dtype.name
    if name in _VIEW_ENCODE:
        return v.view(_VIEW_ENCODE[name]), name
    return v, name


def _decode(v: np.ndarray, dtype_name: str) -> np.ndarray:
    if dtype_name in _VIEW_ENCODE:
        import ml_dtypes

        return v.view(np.dtype(getattr(ml_dtypes, dtype_name)))
    return v


def _flatten_with_paths(tree: Pytree) -> List[Tuple[str, np.ndarray]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((key, np.asarray(leaf)))
    return out


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------------
    def save(self, step: int, state: Pytree, *, blocking: bool = False,
             extra_manifest: Optional[Dict[str, Any]] = None) -> None:
        """Snapshot on caller thread; serialize on background thread."""
        self.wait()  # one in-flight save at a time
        host_state = jax.tree.map(lambda x: np.asarray(x), state)
        leaves = [(k, *_encode(v)) for k, v in _flatten_with_paths(host_state)]
        manifest = {
            "step": int(step),
            "leaves": [
                {"key": k, "shape": list(v.shape), "dtype": dt}
                for k, v, dt in leaves
            ],
        }
        if extra_manifest:
            manifest.update(extra_manifest)

        def _write():
            try:
                tmp = os.path.join(self.dir, f"step_{step:08d}.tmp")
                final = os.path.join(self.dir, f"step_{step:08d}")
                if os.path.exists(tmp):
                    shutil.rmtree(tmp)
                os.makedirs(tmp)
                np.savez(os.path.join(tmp, "arrays.npz"),
                         **{k: v for k, v, _ in leaves})
                with open(os.path.join(tmp, "manifest.json"), "w") as f:
                    json.dump(manifest, f, indent=1)
                    f.flush()
                    os.fsync(f.fileno())
                if os.path.exists(final):
                    shutil.rmtree(final)
                os.rename(tmp, final)
                self._rotate()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        if blocking:
            _write()
            if self._error:
                raise self._error
        else:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    # ------------------------------------------------------------------
    def _steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def _rotate(self) -> None:
        steps = self._steps()
        for s in steps[: max(0, len(steps) - self.keep)]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"))

    def latest_step(self) -> Optional[int]:
        self.wait()  # join any in-flight save: commit-before-read
        steps = self._steps()
        return steps[-1] if steps else None

    # ------------------------------------------------------------------
    def restore(self, like: Pytree, step: Optional[int] = None,
                shardings: Optional[Pytree] = None) -> Tuple[Pytree, int]:
        """Restore into the structure of ``like``.  With ``shardings``,
        leaves are placed directly with jax.device_put (resharding on
        elastic restarts)."""
        self.wait()
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        path = os.path.join(self.dir, f"step_{step:08d}")
        arrays = np.load(os.path.join(path, "arrays.npz"))
        with open(os.path.join(path, "manifest.json")) as f:
            dtypes = {l["key"]: l["dtype"] for l in json.load(f)["leaves"]}

        flat = jax.tree_util.tree_flatten_with_path(like)
        keys = [
            _SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in pth)
            for pth, _ in flat[0]
        ]
        shard_leaves = (
            jax.tree.leaves(shardings) if shardings is not None else [None] * len(keys)
        )
        leaves = []
        for key, (_, leaf), sh in zip(keys, flat[0], shard_leaves):
            arr = _decode(arrays[key], dtypes.get(key, str(arrays[key].dtype)))
            want = getattr(leaf, "shape", None)
            if want is not None and tuple(arr.shape) != tuple(want):
                raise ValueError(f"shape mismatch for {key}: {arr.shape} vs {want}")
            leaves.append(jax.device_put(arr, sh) if sh is not None else arr)
        return jax.tree_util.tree_unflatten(flat[1], leaves), step
