"""Multi-pod dry-run, single cell: lower + compile one (arch × shape) on
the 2×16×16 production mesh and print the compiler's own evidence that
the distribution is coherent (memory fits, collectives sane).

    PYTHONPATH=src python examples/multipod_dryrun.py --arch glm4-9b \
        --shape decode_32k

NOTE: sets XLA_FLAGS before importing jax — run as a standalone script.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse  # noqa: E402
import sys  # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.cells import analyze_compiled, build_cell  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4-9b")
    ap.add_argument("--shape", default="decode_32k")
    ap.add_argument("--single-pod", action="store_true")
    args = ap.parse_args()

    mesh = make_production_mesh(multi_pod=not args.single_pod)
    print(f"mesh: {mesh.devices.shape} axes={mesh.axis_names}")
    cell = build_cell(args.arch, args.shape, mesh)
    with mesh:
        lowered = cell.fn.lower(*cell.args)
        compiled = lowered.compile()
        print(compiled.memory_analysis())
        ca = compiled.cost_analysis()
        print({k: ca[k] for k in ("flops", "bytes accessed") if k in ca})
    st = analyze_compiled(compiled)
    hs = st.get("hlo_stats", {})
    print(f"\nper-device (trip-count-aware):")
    print(f"  flops            : {hs.get('flops', 0):.3e}")
    print(f"  hbm bytes        : {hs.get('hbm_bytes', 0):.3e}")
    print(f"  collective bytes : {hs.get('total_collective_bytes', 0):.3e}")
    print(f"  collectives      : { {k: int(v) for k, v in hs.get('collective_ops', {}).items()} }")
    print(f"  temp HBM         : {st.get('temp_size_in_bytes', 0)/1e9:.2f} GB/device")
    print("\nOK: the production mesh shards this cell coherently.")


if __name__ == "__main__":
    main()
