"""Fan-out sweep as a stage graph: one shared data stage, N concurrent
train stages with injected overrides, one compare/visualize stage.

    python examples/pipeline_sweep.py

The graph (plan and data independent; trains fan out, compare joins):

    plan ──┬─> train-0 ─┐
    data ──┼─> train-1 ─┼─> compare ─> visualize
           └─> train-2 ─┘

Each train stage gets its own learning rate via parameter injection
(`optimizer.lr=...`), logs metrics under its own stage column of the
shared run record, and checkpoints under its own artifact dir.  The
compare stage reads every train's history back from provenance and ranks
the sweep; stage_start/stage_end events prove the trains overlapped.

A cross-run StageCache is attached: the first run executes the data
stage and persists its outputs under a content-addressed input hash;
re-running the sweep skips it with a `stage_cached` provenance event.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import (  # noqa: E402
    REGISTRY,
    DataStage,
    PlanStage,
    ProvenanceStore,
    StageCache,
    StageContext,
    StageGraph,
    TrainStage,
    VisualizeStage,
)

LRS = (5e-4, 2e-3, 8e-3)
STEPS = 10


def compare_fn(ctx):
    rows = []
    for i in range(len(LRS)):
        hist = [h for h in ctx.record.metrics()
                if h.get("stage") == f"train-{i}" and "loss" in h]
        final = hist[-1]["loss"] if hist else float("nan")
        rows.append({"stage": f"train-{i}", "lr": LRS[i], "final_loss": final})
    rows.sort(key=lambda r: r["final_loss"])
    ctx.record.log_event("sweep_compare", {"ranking": rows})
    return {"sweep_ranking": rows}


def main():
    t = REGISTRY.get("train-xlstm-125m")
    store = ProvenanceStore("runs")
    record = store.create_run(
        template=f"{t.name}-sweep", template_version=t.version,
        config=t.config_dict(), plan={"slice": None, "status": "pending"},
    )

    g = StageGraph("lr-sweep")
    g.add(PlanStage(stage_goals={"data": "quick_test"}))
    g.add(DataStage())
    for i, lr in enumerate(LRS):
        g.add(TrainStage(f"train-{i}", overrides={"optimizer.lr": lr},
                         state_key=f"state.train-{i}"),
              depends_on=("plan", "data"))
    g.add_fn("compare", compare_fn, outputs=("sweep_ranking",),
             depends_on=tuple(f"train-{i}" for i in range(len(LRS))))
    g.add(VisualizeStage(filename="sweep.png"), depends_on=("compare",))

    print(g.render())
    cache = StageCache()
    ctx = StageContext(template=t, record=record, cache=cache,
                       params={"steps_override": STEPS})
    results = g.execute(ctx, max_workers=4)

    print("\nstage timings:")
    for name, r in results.items():
        note = "  (cache hit)" if r.cached else ""
        print(f"  {name:12s} ok={r.ok}  start=+{r.started_at % 1000:7.3f}s "
              f"dur={r.duration_s:6.2f}s{note}")
    cached_events = [e for e in record.stage_events()
                     if e["kind"] == "stage_cached"]
    if cached_events:
        print(f"\nstages skipped via cross-run cache: "
              f"{[e['stage'] for e in cached_events]}")
    else:
        print("\ncold cache: data stage executed and persisted "
              "(re-run to see the stage_cached hit)")

    # demonstrate concurrency: at least two train stages overlapped
    spans = [(results[f"train-{i}"].started_at,
              results[f"train-{i}"].started_at + results[f"train-{i}"].duration_s)
             for i in range(len(LRS))]
    spans.sort()
    overlaps = sum(1 for a, b in zip(spans, spans[1:]) if b[0] < a[1])
    print(f"\nconcurrent train overlaps: {overlaps}")

    print("\nsweep ranking (best first):")
    for row in ctx.get("sweep_ranking"):
        print(f"  {row['stage']}: lr={row['lr']:.0e} "
              f"final_loss={row['final_loss']:.4f}")
    print(f"\nartifacts: {record.artifacts_dir}")
    assert overlaps >= 1, "train stages did not run concurrently"


if __name__ == "__main__":
    main()
