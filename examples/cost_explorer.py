"""Cost/performance exploration — the paper's Fig. 4 workflow through
`repro.core.explore`: 'Which hardware should I run my training job on,
and what will it cost?' answered without naming a single instance type.

    PYTHONPATH=src python examples/cost_explorer.py --arch glm4-9b \
        --shape train_4k --budget 500

The full walkthrough (grid axes, Pareto frontier, scaling knees,
retry-aware expected cost, the `explore` CLI and the Markdown report)
lives in docs/exploring-cost-performance.md.
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.explore import (  # noqa: E402
    ExploreSpec,
    explore,
    frontier_table,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4-9b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--budget", type=float, default=None, help="$/hour cap")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="max step time")
    ap.add_argument("--preempt-rate", type=float, default=0.02,
                    help="preemptions per chip-hour folded into the "
                         "expected-cost column")
    args = ap.parse_args()

    # One spec, every question: all three goals over a chip-count axis,
    # shared constraints, a failure model for the E[$] column.
    spec = ExploreSpec(
        archs=(args.arch,),
        shapes=(args.shape,),
        goals=("quick_test", "production", "exploration"),
        chip_counts=(16, 32, 64, 128),
        budget_usd_per_hour=args.budget,
        max_step_seconds=args.deadline_ms / 1e3 if args.deadline_ms else None,
        preempt_rate_per_chip_hour=args.preempt_rate,
        steps=2000,
    )
    result = explore(spec)

    print(f"workload: {args.arch} × {args.shape} — "
          f"{len(result.cells)} cells, {result.feasible_cells} feasible")
    print("\n-- Pareto frontier (step time × $/Mtok × $/h, "
          "retry-aware E[$]) --")
    print(frontier_table(result))

    # generation sweep (Fig. 4a/4b analogue): the scaling report groups
    # the same grid by chip generation and finds each family's knee
    print("\n-- scaling per chip generation (like the paper's "
          "m6a->m7a->m8a) --")
    for fam in result.scaling:
        knee = (f"knee at {fam.knee_chips} chips" if fam.knee_chips
                else "no efficient point")
        print(f"  {fam.generation:4s} ({knee})")
        for r in fam.rows:
            print(f"    {r.chips:5d} chips  {r.slice_name:>12s}  "
                  f"step={r.step_s*1e3:9.1f}ms  "
                  f"eff={r.efficiency*100:5.1f}%  "
                  f"$/Mtok={r.cost_per_mtok:.4f}")


if __name__ == "__main__":
    main()
