"""Cost/performance exploration — the paper's Fig. 4 workflow as an
interactive tool.  'Which hardware should I run my training job on, and
what will it cost?' answered without naming a single instance type.

    PYTHONPATH=src python examples/cost_explorer.py --arch glm4-9b \
        --shape train_4k --budget 500
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import ResourceIntent, plan  # noqa: E402
from repro.core.catalog import CHIPS  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="glm4-9b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--budget", type=float, default=None, help="$/hour cap")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="max step time")
    args = ap.parse_args()

    print(f"workload: {args.arch} × {args.shape}")
    print(f"{'':14s} {'goal=quick_test':^38s} {'goal=production':^38s}")

    for goal in ("quick_test", "production", "exploration"):
        intent = ResourceIntent(
            arch=args.arch, shape=args.shape, goal=goal,
            budget_usd_per_hour=args.budget,
            max_step_seconds=args.deadline_ms / 1e3 if args.deadline_ms else None,
        )
        choices = plan(intent, top_k=3)
        print(f"\n-- {goal} --")
        if not choices:
            print("   no feasible plan under constraints")
            continue
        for i, c in enumerate(choices):
            print(f"  #{i+1} {c.summary}")

    # generation sweep (Fig. 4a/4b analogue): same chip count per generation
    print("\n-- chip-generation sweep (64 chips, like the paper's "
          "m6a->m7a->m8a) --")
    for gen in CHIPS:
        intent = ResourceIntent(arch=args.arch, shape=args.shape,
                                goal="exploration", chip_generation=gen,
                                min_chips=64, max_chips=64)
        c = plan(intent, top_k=1)
        if c:
            e = c[0].est
            print(f"  {gen:4s} step={e.step_s*1e3:9.1f}ms  "
                  f"cost/step=${e.cost_per_step:.5f}  "
                  f"bottleneck={e.bottleneck}")


if __name__ == "__main__":
    main()
