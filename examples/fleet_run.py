"""Fleet run: many concurrent workflows through one shared executor.

The multi-cloud platform promise, scaled past a single run: a RunQueue
schedules 4 concurrent workflow runs against one shared stage-executor
backend with per-run fairness, while a chaos hook kills a worker
mid-stage — the lease reaper requeues the stage and every run still
completes:

    python examples/fleet_run.py                         # worker queue
    python examples/fleet_run.py --executor processes    # process pool
    python examples/fleet_run.py --executor threads --workers 8

The stage graphs here are deliberately CPU-bound pure-Python pipelines
(the Data/Eval-stage profile) so `--executor processes` demonstrates the
GIL escape and `--executor workers` demonstrates lease/heartbeat fault
tolerance; swap in `RunQueue.submit_workflow(template, store, ...)` to
drive full `repro` templates through the same fleet.
"""
import argparse
import os
import sys
import threading
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import (  # noqa: E402
    RunQueue,
    StageContext,
    StageGraph,
    WorkerQueueExecutor,
    make_executor,
)
from repro.core.graph import Stage  # noqa: E402


class CrunchStage(Stage):
    """CPU-bound pure function — picklable, so every backend (threads,
    process pool, worker queue) can execute it."""

    process_safe = True

    def __init__(self, name, iters=60_000, inputs=(), outputs=()):
        super().__init__(name)
        self.iters = iters
        self.inputs = tuple(inputs)
        self.outputs = tuple(outputs)

    def run(self, ctx):
        acc = sum(hash(k) % 97 for k in self.inputs)
        for i in range(self.iters):
            acc = (acc * 6364136223846793005 + i) % (2 ** 63)
        return {k: f"{k}:{acc % 10_000}:{os.getpid()}" for k in self.outputs}


def pipeline_graph(run_idx, iters):
    """prep -> (tokenize | featurize) -> merge, per run."""
    g = StageGraph(f"pipeline{run_idx}")
    g.add(CrunchStage("prep", iters, outputs=("raw",)))
    g.add(CrunchStage("tokenize", iters, inputs=("raw",), outputs=("tok",)),
          depends_on=("prep",))
    g.add(CrunchStage("featurize", iters, inputs=("raw",), outputs=("feat",)),
          depends_on=("prep",))
    g.add(CrunchStage("merge", iters, inputs=("tok", "feat"),
                      outputs=("table",)),
          depends_on=("tokenize", "featurize"))
    return g


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--executor", default="workers",
                    choices=["threads", "processes", "workers"])
    ap.add_argument("--workers", type=int, default=3)
    ap.add_argument("--runs", type=int, default=4)
    ap.add_argument("--iters", type=int, default=60_000)
    args = ap.parse_args()

    shared = make_executor(args.executor, workers=args.workers)
    rq = RunQueue(shared, max_active=args.runs)
    print(f"fleet    : {args.runs} runs over one shared "
          f"{type(shared).__name__} (capacity {shared.capacity()})")

    t0 = time.perf_counter()
    tickets = []
    for i in range(args.runs):
        def drive(view, i=i):
            ctx = StageContext(template=None, record=None)
            pipeline_graph(i, args.iters).execute(ctx, executor=view)
            return dict(ctx.outputs)

        tickets.append(rq.submit(f"pipeline{i}", drive))

    # chaos: on the worker-queue backend, kill a worker mid-fleet — the
    # stale-lease reaper requeues its stage and recruits a replacement
    if isinstance(shared, WorkerQueueExecutor):
        def assassin():
            victim = shared.kill_worker()
            print(f"chaos    : killed worker {victim!r} mid-fleet")

        threading.Timer(0.05, assassin).start()

    ok = rq.drain(timeout=300)
    wall = time.perf_counter() - t0
    assert ok, "fleet failed to drain"

    pids = set()
    for t in tickets:
        outputs = t.result()
        assert t.status == "done" and len(outputs) == 4, (t, outputs)
        pids.update(v.rsplit(":", 1)[1] for v in outputs.values())
        print(f"  {t.name:10s} done  peak in-flight {t.max_in_flight}  "
              f"table={outputs['table'].split(':')[1]}")
    print(f"wall     : {wall:.2f}s  "
          f"({args.runs / wall:.1f} runs/s, {len(pids)} worker pid(s))")
    print(f"executor : {shared.stats()}")
    rq.shutdown()
    shared.shutdown()
    assert all(t.status == "done" for t in tickets)
    print("fleet complete: every run survived the chaos drill")


if __name__ == "__main__":
    main()
