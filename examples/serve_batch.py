"""Continuous-batching serving demo: more requests than slots, mixed
lengths, slot refill, greedy + sampled decoding.

    PYTHONPATH=src python examples/serve_batch.py
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import get_config, reduced  # noqa: E402
from repro.models import build_model  # noqa: E402
from repro.serve import Request, ServeEngine  # noqa: E402


def main():
    cfg = reduced(get_config("qwen2-1.5b"))
    model = build_model(cfg)
    params, _ = model.init(jax.random.PRNGKey(0))
    engine = ServeEngine(model, params, max_batch=4, max_seq=96, eos_id=-1)

    rng = np.random.default_rng(0)
    n_requests = 12
    for i in range(n_requests):
        engine.submit(Request(
            uid=i,
            prompt=rng.integers(1, cfg.vocab_size, 8 + (i % 3) * 4).astype(np.int32),
            max_new_tokens=8 + (i % 4) * 6,
            temperature=0.0 if i % 2 == 0 else 0.8,
        ))

    t0 = time.time()
    done = engine.run()
    dt = time.time() - t0
    toks = sum(len(c.tokens) for c in done)

    print(f"requests   : {len(done)} (batch slots: {engine.max_batch})")
    print(f"tokens     : {toks} in {dt:.2f}s -> {toks/dt:,.1f} tok/s")
    for c in sorted(done, key=lambda c: c.uid)[:6]:
        print(f"  uid={c.uid:2d} prompt_len={c.prompt_len:2d} "
              f"new={len(c.tokens):2d} reason={c.finished_reason:6s} "
              f"tokens={c.tokens[:6]}…")
    assert len(done) == n_requests


if __name__ == "__main__":
    main()
