"""Resilience walkthrough: stage retry, crash, and resume.

Three acts, all on the reduced CPU config:

  1. a workflow survives an injected stage failure via per-stage retry
     (provenance: stage_failed -> stage_retry -> stage_end);
  2. the same workflow is killed outright (no retries) — the run
     directory keeps its stage manifest and committed checkpoints;
  3. the crashed run is resumed: completed stages are skipped
     (stage_cached with resume=true), training restarts from its
     checkpoint, and the final checks match an uninterrupted run.

    python examples/resilient_run.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import (  # noqa: E402
    REGISTRY,
    FailureSchedule,
    InjectedFailure,
    ProvenanceStore,
    RestartPolicy,
    run_workflow,
)


def main():
    store = ProvenanceStore("runs")
    t = REGISTRY.get("train-xlstm-125m")

    print("=== act 1: retry absorbs a stage failure =======================")
    res = run_workflow(
        t, store, steps_override=8,
        failures=FailureSchedule(fail_stages={"data": 1}),
        stage_retry=RestartPolicy(max_restarts=2, backoff_s=0.0),
    )
    trail = [e["kind"] for e in res.record.stage_events()
             if e.get("stage") == "data"]
    print(f"data-stage trail : {' -> '.join(trail)}")
    print(f"attempts         : {res.stage_results['data'].attempts}")
    assert res.ok and "stage_retry" in trail

    print("\n=== act 2: crash (no retries) ==================================")
    before = set(store.list_runs())
    try:
        run_workflow(t, store, steps_override=8,
                     failures=FailureSchedule(fail_stages={"train": 1}))
    except InjectedFailure as e:
        (crashed,) = set(store.list_runs()) - before
        print(f"run {crashed} died: {e}")

    print("\n=== act 3: resume ==============================================")
    res = run_workflow(t, store, steps_override=8, resume=crashed)
    for name, sr in res.stage_results.items():
        status = "skipped (resume)" if sr.resumed else "ran"
        print(f"  {name:10s} {status}")
    assert res.ok
    assert res.stage_results["plan"].resumed
    assert res.stage_results["data"].resumed
    assert not res.stage_results["train"].resumed
    print(f"\nresumed run ok; checks: "
          f"{ {k: v[0] for k, v in res.checks.items()} }")


if __name__ == "__main__":
    main()
