"""End-to-end driver: train a ~100M-parameter qwen2-family model for a few
hundred steps through the full platform stack (data pipeline, AdamW,
envelope with checkpoints + straggler watch, provenance).

Default is the ~100M config / 200 steps (expect ~1–2 h on this CPU
container; it is sized for a real accelerator).  ``--preset smoke`` runs
a ~7M model for 60 steps in a couple of minutes — same code path.

    PYTHONPATH=src python examples/train_lm.py --preset smoke
    PYTHONPATH=src python examples/train_lm.py            # full ~100M
"""
import argparse
import dataclasses
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.checkpoint import Checkpointer  # noqa: E402
from repro.configs import get_config  # noqa: E402
from repro.configs.base import ShapeConfig, reduced  # noqa: E402
from repro.core.envelope import ExecutionEnvelope  # noqa: E402
from repro.core.provenance import ProvenanceStore  # noqa: E402
from repro.data import DataConfig, make_stream  # noqa: E402
from repro.models import build_model  # noqa: E402
from repro.parallel import Plan  # noqa: E402
from repro.train import OptimizerConfig, init_train_state, make_train_step  # noqa: E402

PRESETS = {
    # ~100M params: 12L, d=768, 12H — the assignment's end-to-end driver
    "100m": dict(num_layers=12, d_model=768, num_heads=12, num_kv_heads=4,
                 head_dim=64, d_ff=2048, vocab_size=32768, batch=16, seq=512,
                 steps=200),
    # ~7M: CI-sized, identical code path
    "smoke": dict(num_layers=4, d_model=256, num_heads=4, num_kv_heads=2,
                  head_dim=64, d_ff=1024, vocab_size=4096, batch=4, seq=128,
                  steps=60),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="100m", choices=sorted(PRESETS))
    ap.add_argument("--steps", type=int, default=None)
    ap.add_argument("--lr", type=float, default=3e-3)
    args = ap.parse_args()
    p = PRESETS[args.preset]
    steps = args.steps or p["steps"]

    cfg = dataclasses.replace(
        get_config("qwen2-1.5b"),
        name=f"qwen2-{args.preset}",
        num_layers=p["num_layers"], d_model=p["d_model"],
        num_heads=p["num_heads"], num_kv_heads=p["num_kv_heads"],
        head_dim=p["head_dim"], d_ff=p["d_ff"], vocab_size=p["vocab_size"],
        tie_embeddings=True,
    )
    model = build_model(cfg)
    shape = ShapeConfig("train", p["seq"], p["batch"], "train")
    opt = OptimizerConfig(lr=args.lr, warmup_steps=max(steps // 20, 5),
                          total_steps=steps, weight_decay=0.01)
    plan = Plan(remat="none", microbatch=1)

    stream = make_stream(cfg, shape, DataConfig(seed=0, vocab_size=min(8192, cfg.vocab_size)))
    step_jit = jax.jit(make_train_step(model, opt, plan))

    store = ProvenanceStore("runs")
    record = store.create_run(
        template=f"example-train-{args.preset}", template_version="1",
        config={"preset": p, "lr": args.lr}, plan={"remat": plan.remat},
    )
    env = ExecutionEnvelope(
        record, checkpointer=Checkpointer(f"{record.artifacts_dir}/ckpt", keep=2),
        checkpoint_every=max(steps // 4, 10),
    )

    n_params = {}

    def init_fn():
        state = init_train_state(model, jax.random.PRNGKey(0), opt, plan)
        n_params["n"] = sum(x.size for x in jax.tree.leaves(state["params"]))
        return state

    def step_fn(state, step):
        batch = {k: jnp.asarray(v) for k, v in stream.batch_at(step).items()}
        state, metrics = step_jit(state, batch)
        if step % 10 == 0:
            print(f"  step {step:4d} loss={float(metrics['loss']):.4f} "
                  f"lr={float(metrics['lr']):.2e}", flush=True)
        return state, metrics

    t0 = time.time()
    env.run(init_state=init_fn, step_fn=step_fn, num_steps=steps)
    dt = time.time() - t0
    hist = record.metrics()
    losses = [h["loss"] for h in hist]
    print(f"\nparams      : {n_params['n']/1e6:.1f}M")
    print(f"steps       : {len(losses)} in {dt:.0f}s "
          f"({p['batch']*p['seq']*len(losses)/dt:,.0f} tok/s)")
    print(f"loss        : {losses[0]:.4f} -> {losses[-1]:.4f}")
    print(f"run record  : {record.dir}")
    assert losses[-1] < losses[0]


if __name__ == "__main__":
    main()
