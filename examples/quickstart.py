"""Quickstart: the Adviser experience in six lines of intent.

A scientist who knows *what* they want (train qwen2 on their data, under
budget) and nothing about meshes, shardings, remat or chip SKUs:

    python examples/quickstart.py

What happens: template lookup -> planner (intent -> slice + mesh + plan)
-> budget gate -> envelope-run (checkpoints, structured logs) ->
validation checks -> provenance record with a loss curve.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import REGISTRY, ProvenanceStore, run_workflow  # noqa: E402


def main():
    store = ProvenanceStore("runs")
    template = REGISTRY.get("train-qwen2-1.5b")

    print(f"template : {template.name} v{template.version}")
    print(f"           {template.description}")

    result = run_workflow(template, store, steps_override=20)

    print(f"\nrun      : {result.record.run_id}")
    if result.plan_choice:
        print(f"plan     : {result.plan_choice.summary}")
    print("checks   :")
    for name, (ok, detail) in result.checks.items():
        print(f"  [{'PASS' if ok else 'FAIL'}] {name:20s} {detail}")
    hist = result.record.metrics()
    print(f"\nloss     : {hist[0]['loss']:.4f} -> {hist[-1]['loss']:.4f} "
          f"over {len(hist)} steps")
    print(f"artifacts: {result.record.artifacts_dir}")
    assert result.ok


if __name__ == "__main__":
    main()
