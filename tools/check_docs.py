"""Docs link + symbol checker: fail on broken relative links/anchors in
README.md and docs/*.md, and on backtick-quoted ``repro.*`` references
that no longer resolve, so documentation can't rot silently.

    python tools/check_docs.py            # check the repo's docs
    python tools/check_docs.py --root X   # check another tree

Checks every markdown inline link ``[text](target)``:
  * external targets (http/https/mailto) are skipped (no network in CI);
  * pure-anchor targets (``#section``) must match a heading in the file;
  * relative targets must resolve to an existing file or directory
    (anchors on relative targets are validated against that file's
    headings when it is markdown).

Checks every inline code span that names a dotted ``repro.…`` path
(e.g. `repro.core.explore.ExploreSpec`): the module must import and the
trailing symbol must exist — the docs-rot class the link checker can't
see (a renamed function leaves every link intact).

Used by CI (see .github/workflows/ci.yml) and wrapped as a tier-1 test
in tests/test_docs.py.
"""
from __future__ import annotations

import argparse
import glob
import importlib
import os
import re
import sys
from typing import List, Tuple

# image links are extracted first and replaced by a placeholder so the
# outer half of a nested [![badge](img)](target) still matches _LINK_RE
_IMG_RE = re.compile(r"!\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
_LINK_RE = re.compile(r"\[[^\]\[]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
_HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
_CODE_FENCE_RE = re.compile(r"```.*?```", re.DOTALL)
# inline code spans that look like a dotted repro path: `repro.core.plan`
# (plain dotted names only — spans with spaces, slashes, parens or
# flags are commands/expressions, not symbol references)
_CODE_SPAN_RE = re.compile(r"`([^`\n]+)`")
_SYMBOL_RE = re.compile(r"^repro(\.\w+)+$")


def _anchor_of(heading: str) -> str:
    """GitHub's heading -> anchor slug (enough of it for our docs)."""
    slug = heading.strip().lower()
    slug = re.sub(r"[`*_~]", "", slug)
    slug = re.sub(r"[^\w\- ]", "", slug)
    return slug.replace(" ", "-")


def _headings(md_path: str) -> List[str]:
    with open(md_path, encoding="utf-8") as f:
        text = _CODE_FENCE_RE.sub("", f.read())
    return [_anchor_of(h) for h in _HEADING_RE.findall(text)]


def _resolvable(ref: str, src_dir: str) -> bool:
    """Does dotted path ``ref`` import (as a module, or as module +
    trailing attribute)?  ``src_dir`` is prepended to sys.path so the
    check works without PYTHONPATH=src."""
    if src_dir and src_dir not in sys.path:
        sys.path.insert(0, src_dir)
    try:
        importlib.import_module(ref)
        return True
    except ImportError:
        pass
    except Exception:
        return False
    mod, _, attr = ref.rpartition(".")
    try:
        return hasattr(importlib.import_module(mod), attr)
    except Exception:
        return False


def check_symbols(path: str, root: str) -> List[str]:
    """Unresolvable ``repro.*`` code-span references in one file."""
    errors: List[str] = []
    rel = os.path.relpath(path, root)
    with open(path, encoding="utf-8") as f:
        text = _CODE_FENCE_RE.sub("", f.read())
    src_dir = os.path.join(root, "src")
    seen = set()
    for m in _CODE_SPAN_RE.finditer(text):
        ref = m.group(1).strip()
        if ref in seen or not _SYMBOL_RE.match(ref):
            continue
        seen.add(ref)
        if not _resolvable(ref, src_dir):
            errors.append(f"{rel}: unresolvable reference `{ref}` "
                          f"(import failed and no such attribute)")
    return errors


def doc_files(root: str) -> List[str]:
    files = []
    readme = os.path.join(root, "README.md")
    if os.path.exists(readme):
        files.append(readme)
    files.extend(sorted(glob.glob(os.path.join(root, "docs", "*.md"))))
    return files


def check_file(path: str, root: str) -> List[str]:
    """Broken-link descriptions for one markdown file (empty = clean)."""
    errors: List[str] = []
    rel = os.path.relpath(path, root)
    with open(path, encoding="utf-8") as f:
        text = _CODE_FENCE_RE.sub("", f.read())
    targets = [m.group(1) for m in _IMG_RE.finditer(text)]
    text = _IMG_RE.sub("IMG", text)
    targets += [m.group(1) for m in _LINK_RE.finditer(text)]
    for target in targets:
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        if target.startswith("#"):
            if _anchor_of(target[1:]) not in _headings(path):
                errors.append(f"{rel}: broken anchor {target!r}")
            continue
        file_part, _, anchor = target.partition("#")
        dest = os.path.normpath(
            os.path.join(os.path.dirname(path), file_part))
        if not os.path.exists(dest):
            errors.append(f"{rel}: broken link {target!r} "
                          f"(no such file {os.path.relpath(dest, root)!r})")
            continue
        if anchor and dest.endswith(".md"):
            if _anchor_of(anchor) not in _headings(dest):
                errors.append(f"{rel}: broken anchor {target!r}")
    return errors


def check_tree(root: str, symbols: bool = True) -> Tuple[List[str], List[str]]:
    """(checked files, errors) for README.md + docs/*.md under root."""
    files = doc_files(root)
    errors: List[str] = []
    for path in files:
        errors.extend(check_file(path, root))
        if symbols:
            errors.extend(check_symbols(path, root))
    return files, errors


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--root", default=os.path.join(
        os.path.dirname(os.path.abspath(__file__)), ".."))
    ap.add_argument("--no-symbols", action="store_true",
                    help="skip the repro.* import-resolution check "
                         "(links/anchors only)")
    args = ap.parse_args()
    root = os.path.abspath(args.root)
    files, errors = check_tree(root, symbols=not args.no_symbols)
    if not files:
        print(f"no docs found under {root}", file=sys.stderr)
        return 2
    for e in errors:
        print(f"BROKEN  {e}", file=sys.stderr)
    print(f"checked {len(files)} files, {len(errors)} broken links")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
